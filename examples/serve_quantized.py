"""End-to-end driver (the paper is an inference paper): serve a small
LM with batched requests, weights stored as HOBFLOPS9 bitplane codes —
the paper's custom-precision FP as the memory-bandwidth feature of
decode.  Compares output agreement and HBM weight footprint vs bf16.

Run: PYTHONPATH=src python examples/serve_quantized.py
"""
import sys

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs import smoke_config
from repro.launch.serve import serve_demo
from repro.models import model_schema
from repro.models.schema import init_params
from repro.quant.apply import quantize_params, quantized_bytes


def main():
    cfg = smoke_config("qwen3-4b")
    print(f"arch: {cfg.name} ({cfg.n_layers}L d={cfg.d_model})")

    params = init_params(model_schema(cfg), jax.random.PRNGKey(0))
    qp, _ = quantize_params(params, cfg, "hobflops9")
    qb, db = quantized_bytes(qp)
    print(f"quantized weight families: {qb/1e6:.2f} MB as hobflops9 "
          f"bitplanes vs {db/1e6:.2f} MB as bf16 "
          f"({db/max(qb,1):.2f}x smaller)\n")

    print("--- serving with bf16 weights ---")
    toks_f = serve_demo(cfg, batch=4, prompt_len=32, gen_len=12)
    print("\n--- serving with hobflops9 bitplane weights ---")
    toks_q = serve_demo(cfg, batch=4, prompt_len=32, gen_len=12,
                        quant="hobflops9")
    agree = (toks_f == toks_q).mean()
    print(f"\ngreedy token agreement f32 vs hobflops9: {agree:.2%} "
          f"(9-bit weights on an untrained model)")


if __name__ == "__main__":
    main()
