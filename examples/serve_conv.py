"""Lane-batched serving demo: many requests through one wave.

Builds a small HOBFLOPS graph (3x3 conv -> pointwise -> maxpool),
prints its per-node summary, then serves a queue of heterogeneous
requests (single images and small mini-batches) through
:class:`ConvServeEngine` — each wave one compiled resident call, one
encode, one decode, results sliced back per request bit-exactly
(checked against per-request ``graph.run`` with ``--check``).

Launch blocks come from the ``tuned_conv_blocks`` disk cache
(``.hobflops_tune.json`` by default, ``HOBFLOPS_TUNE_CACHE`` to
override), so a second run of this example skips the autotune sweep.

With ``--overload`` the demo also floods a small-bucket engine that
has a cheaper-precision variant registered (DESIGN.md §11): sustained
queue pressure steps the precision ladder down, each response is
tagged with the precision that served it, and pressure relief steps
back up — precision is shed before requests are.

Run: PYTHONPATH=src python examples/serve_conv.py [--fmt hobflops9]
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import numpy as np

from repro.core.fpformat import HOBFLOPS_FORMATS
from repro.kernels.conv2d_bitslice.network import NetworkGraph
from repro.serve_conv import (ConvRequest, ConvServeEngine, ServePolicy,
                              tuned_conv_blocks)


def overload_demo(g, hwc, rng, degrade_fmt):
    """Flood a tiny-bucket engine so the precision ladder engages."""
    g_cheap = g.with_precision(HOBFLOPS_FORMATS[degrade_fmt])
    eng = ConvServeEngine(
        g, hwc, max_batch=2,
        policy=ServePolicy(degrade_queue_factor=1.0, degrade_patience=2,
                           recover_patience=1))
    eng.register_degraded(g_cheap, degrade_fmt)
    for i in range(10):
        eng.submit(ConvRequest(i, rng.standard_normal(hwc)
                               .astype(np.float32)))
    done = eng.run()
    ladder = [f"{r.rid}:{r.precision}" for r in done]
    print(f"overload: {' '.join(ladder)}")
    st = eng.stats()["degradation"]
    print(f"  activations={st['activations']} "
          f"images_by_level={st['images_by_level']}")
    # relief: one lightly-loaded wave steps back to full precision
    eng.submit(ConvRequest(99, rng.standard_normal(hwc)
                           .astype(np.float32)))
    eng.run()
    eng.submit(ConvRequest(100, rng.standard_normal(hwc)
                           .astype(np.float32)))
    last = eng.run()[0]
    print(f"  after relief: request {last.rid} served at "
          f"{last.precision!r} (level {last.level})")
    for r in done + [last]:
        graph = g if r.level == 0 else g_cheap
        solo = np.asarray(graph.run(r.image[None]))[0]
        assert (np.asarray(r.out) == solo).all(), r.rid
    print("  every response bit-exact at its served precision")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fmt", default="hobflops8",
                    choices=sorted(HOBFLOPS_FORMATS))
    ap.add_argument("--hw", type=int, default=8)
    ap.add_argument("--cin", type=int, default=8)
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--check", action="store_true",
                    help="verify each request vs per-request graph.run")
    ap.add_argument("--overload", action="store_true",
                    help="demo the precision-degradation ladder")
    args = ap.parse_args()

    fmt = HOBFLOPS_FORMATS[args.fmt]
    rng = np.random.default_rng(0)
    k1 = (rng.standard_normal((3, 3, args.cin, args.cin)) * 0.3) \
        .astype(np.float32)
    k2 = (rng.standard_normal((1, 1, args.cin, args.cin)) * 0.3) \
        .astype(np.float32)

    hwc = (args.hw, args.hw, args.cin)
    img1 = rng.standard_normal((1,) + hwc).astype(np.float32)
    t0 = time.time()
    blocks, _ = tuned_conv_blocks(
        img1, k1, fmt=fmt, iters=1,
        candidates=[{"c_unroll": 4, "m_block": m} for m in (8, 128)])
    print(f"launch blocks {blocks} ({time.time() - t0:.2f}s — cached "
          f"runs skip the sweep)")

    # build the graph WITH the tuned launch blocks: both runners thread
    # them into the kernel launch (NetworkGraph.conv(blocks=...))
    g = NetworkGraph(fmt)
    c1 = g.conv("c1", g.input_name, k1, relu=True, blocks=blocks)
    c2 = g.conv("c2", c1, k2, relu=True, blocks=blocks)
    g.output(g.maxpool2d("head", c2, window=2))

    eng = ConvServeEngine(g, hwc, blocks=blocks, verbose=True)
    # heterogeneous queue: single images and small mini-batches
    pattern = [1, 1, 2, 1, 3, 1, 2, 1, 1, 4]
    sizes = [pattern[i % len(pattern)] for i in range(args.requests)]
    for i, b in enumerate(sizes):
        shape = hwc if b == 1 and i % 2 == 0 else (b,) + hwc
        eng.submit(ConvRequest(
            i, rng.standard_normal(shape).astype(np.float32)))
    t0 = time.time()
    done = eng.run()
    dt = time.time() - t0
    st = eng.stats()
    print(f"served {st['requests_served']} requests "
          f"({st['images_served']} images) in {dt:.2f}s (incl. compile) "
          f"over {st['waves']} waves, mean occupancy "
          f"{st['mean_occupancy']:.2f}")
    print(f"steady-state: {st['images_per_s']:.1f} images/s, "
          f"{st['macs_per_s']:.3e} MACs/s, runner cache "
          f"{st['runner_cache']}")

    if args.check:
        for r in done:
            batched = r.image[None] if r.image.ndim == 3 else r.image
            solo = np.asarray(g.run(batched))
            solo = solo[0] if r.image.ndim == 3 else solo
            assert (np.asarray(r.out) == solo).all(), r.rid
        print(f"bit-exact vs per-request graph.run: "
              f"all {len(done)} requests OK")

    if args.overload:
        degrade = "hobflops8" if args.fmt != "hobflops8" else "hobflops9"
        overload_demo(g, hwc, rng, degrade)


if __name__ == "__main__":
    main()
