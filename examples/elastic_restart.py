"""Fault-tolerance walkthrough: heartbeats -> supervisor detects a dead
host -> plans an elastic re-mesh -> training restarts from the latest
checkpoint onto the smaller fleet (the checkpoint reader re-shards).

Everything is simulated with files on one machine, but the code paths
are the production ones (repro.ft + repro.checkpoint).

Run: PYTHONPATH=src python examples/elastic_restart.py
"""
import sys
import tempfile

sys.path.insert(0, "src")

from repro.configs import smoke_config
from repro.ft import Heartbeat, Supervisor
from repro.launch.train import train_loop
from repro.models.config import ShapeConfig
from repro.optim import OptConfig
from repro.train.step import TrainConfig


def main():
    cfg = smoke_config("gemma-2b")
    shape = ShapeConfig("demo", 64, 4, "train")
    tc = TrainConfig(opt=OptConfig(lr=2e-3, warmup_steps=10,
                                   total_steps=60))
    hosts = [f"host{i}" for i in range(4)]

    with tempfile.TemporaryDirectory() as root:
        hb_dir, ckpt = root + "/hb", root + "/ckpt"
        print("=== 4-host fleet trains; host0 runs the real loop ===")
        train_loop(cfg, shape, steps=30, tc=tc, ckpt_dir=ckpt,
                   ckpt_every=10, hb_dir=hb_dir, host="host0",
                   kill_at=25, log_every=10)
        # other hosts heartbeat in lockstep (simulated)
        for h in hosts[1:3]:
            Heartbeat(hb_dir, h).beat(25, 0.5)
        # host3 died silently: it never wrote a heartbeat

        sup = Supervisor(hb_dir, hosts, chips_per_host=64,
                         model_parallel=16, timeout_s=3600)
        action = sup.poll()
        print(f"\nsupervisor: dead={action['dead']} -> "
              f"action={action['action']}, new mesh "
              f"(pods, data, model) = {action['new_mesh']}")
        assert action["action"] == "remesh"

        print("\n=== restart on the shrunken fleet from the last "
              "checkpoint ===")
        _, losses = train_loop(cfg, shape, steps=60, tc=tc,
                               ckpt_dir=ckpt, ckpt_every=10,
                               hb_dir=hb_dir, host="host0",
                               log_every=10)
        print(f"\nresumed and finished; final loss {losses[-1]:.3f}")


if __name__ == "__main__":
    main()
