"""Quickstart: the HOBFLOPS flow end to end in one minute.

1. Pick a custom FP format (here HOBFLOPS9 = e5m3, MS-FP9-shaped).
2. Generate the gate-level MAC circuit (the in-repo FloPoCo analogue).
3. Technology-map it against the four cell libraries and compare gate
   counts (the paper's synthesis-area experiment).
4. Run a GEMM through the bitslice-parallel MAC and compare against
   both the exact-semantics oracle and plain f32.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core.fpcore import build_mac
from repro.core.fpformat import HOBFLOPS_FORMATS
from repro.core.opt import CELL_LIBS, tech_map
from repro.kernels.bitslice_mac.ops import hobflops_matmul
from repro.kernels.bitslice_mac.ref import hobflops_matmul_f64


def main():
    fmt = HOBFLOPS_FORMATS["hobflops9"]
    print(f"format: hobflops9 = {fmt} "
          f"({fmt.nbits} bits incl. FloPoCo exception field)")

    g = build_mac(fmt)
    print(f"\nMAC circuit: {g.live_gate_count()} raw gates, "
          f"depth {g.depth()}")
    print("tech-mapped gate counts (paper Table 1 libraries + TPU):")
    for lib in ("avx2", "neon", "avx512", "tpu_vpu"):
        mapped = tech_map(g, CELL_LIBS[lib]())
        print(f"  {lib:8s}: {mapped.live_gate_count():4d} ops "
              f"({mapped.op_histogram()})")

    rng = np.random.default_rng(0)
    P, C, M = 8, 16, 64
    a = rng.standard_normal((P, C)).astype(np.float32)
    b = rng.standard_normal((C, M)).astype(np.float32)

    out = np.asarray(hobflops_matmul(a, b, fmt=fmt, backend="jnp"))
    oracle = hobflops_matmul_f64(a, b, fmt)
    exact = a.astype(np.float64) @ b.astype(np.float64)
    print(f"\nGEMM [{P}x{C}] @ [{C}x{M}] in bitslice HOBFLOPS9:")
    print(f"  bit-exact vs oracle : "
          f"{np.array_equal(out, oracle)}")
    print(f"  max |err| vs f64    : {np.abs(out - exact).max():.4f} "
          f"(9-bit arithmetic quantization)")
    print(f"  f64 magnitude scale : {np.abs(exact).max():.4f}")


if __name__ == "__main__":
    main()
