"""Train a small LM on the synthetic pipeline with checkpoint/restart.

Demonstrates the full training substrate on one CPU device: remat'd
scan-over-layers, AdamW + warmup-cosine, async sharded checkpoints,
heartbeats, and crash-resume (kill_at simulates a failure mid-run; the
second call restores and continues bit-for-bit on the data stream).

Run: PYTHONPATH=src python examples/train_lm.py [--steps 120]
"""
import argparse
import sys
import tempfile

sys.path.insert(0, "src")

from repro.configs import smoke_config
from repro.launch.train import train_loop
from repro.models.config import ShapeConfig
from repro.optim import OptConfig
from repro.train.step import TrainConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    shape = ShapeConfig("example", args.seq, args.batch, "train")
    tc = TrainConfig(opt=OptConfig(lr=3e-3, warmup_steps=20,
                                   total_steps=args.steps))

    with tempfile.TemporaryDirectory() as ckpt:
        crash_at = args.steps // 2
        print(f"=== phase 1: train to step {crash_at}, then 'crash' ===")
        _, losses1 = train_loop(cfg, shape, steps=args.steps, tc=tc,
                                ckpt_dir=ckpt, ckpt_every=20,
                                hb_dir=ckpt + "/hb",
                                kill_at=crash_at)
        print(f"\n=== phase 2: restart from checkpoint ===")
        _, losses2 = train_loop(cfg, shape, steps=args.steps, tc=tc,
                                ckpt_dir=ckpt, ckpt_every=20,
                                hb_dir=ckpt + "/hb")
        print(f"\nloss: start {losses1[0]:.3f} -> "
              f"pre-crash {losses1[-1]:.3f} -> final {losses2[-1]:.3f}")
        assert losses2[-1] < losses1[0], "loss should decrease"


if __name__ == "__main__":
    main()
