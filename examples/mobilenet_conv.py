"""The paper's own experiment, grown to real network topology.

Two demos, both computed end-to-end in HOBFLOPS bitslice arithmetic
with activations resident in the plane domain (one encode at the
input, one decode at the output — DESIGN.md §8-§9):

* the original MobileNets-style linear stack (3x3 conv + two pointwise
  convs, ReLU between) through :class:`HobflopsNetwork`;
* a graph topology through :class:`NetworkGraph`: 3x3 conv -> 2x2
  maxpool -> residual pointwise block (skip merged by an in-domain
  ``build_add``) -> strided 3x3 downsample at a *higher* per-layer
  precision (the paper's mixed-precision prototyping pitch) -> 2x2
  avgpool head (add-tree + ``build_scale``, no divider).

The same graphs chained through per-layer f32 boundaries and the
word-parallel softfloat oracles are bit-exact — run with ``--check``.

Run: PYTHONPATH=src python examples/mobilenet_conv.py [--fmt hobflops9]
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import numpy as np

from repro.core.fpformat import HOBFLOPS_FORMATS
from repro.kernels.conv2d_bitslice.network import (ConvLayerSpec,
                                                   HobflopsNetwork,
                                                   NetworkGraph)
from repro.kernels.conv2d_bitslice.ref import conv2d_f32


def run_linear_stack(args, fmt, rng):
    img = rng.standard_normal((1, args.hw, args.hw, args.cin)) \
        .astype(np.float32)
    shapes = [(3, 3, args.cin, args.width),
              (1, 1, args.width, args.width),
              (1, 1, args.width, args.width)]
    kernels = [(rng.standard_normal(s) * 0.2).astype(np.float32)
               for s in shapes]
    net = HobflopsNetwork([ConvLayerSpec(k, fmt, relu=True)
                           for k in kernels])

    t0 = time.time()
    out = np.asarray(net(img))
    dt = time.time() - t0

    f32 = img
    for k in kernels:
        f32 = np.maximum(np.asarray(conv2d_f32(f32, k)), 0.0)
    print(f"{len(kernels)}-layer stack @ {args.hw}x{args.hw}x{args.cin} "
          f"in {args.fmt} (bitslice-resident, incl. compile): {dt:.2f}s")
    print(f"  MACs: {net.macs(img.shape):,}  (1 activation encode, "
          f"1 decode, {len(kernels) - 1} in-domain casts)")
    print(f"  rel err vs f32 conv+relu chain: "
          f"{np.abs(out - f32).max() / np.abs(f32).max():.4f}")
    if args.check:
        rt = np.asarray(net.run_roundtrip(img))
        assert (out == rt).all(), "resident != per-layer roundtrip"
        print("  bit-exact vs per-layer decode/re-encode path: OK")


def run_residual_graph(args, fmt, rng):
    """Residual + strided-downsample + pooled-head topology, mixing the
    base format with a higher-precision late layer."""
    from repro.core.fpformat import FPFormat
    hi = FPFormat(fmt.w_e, fmt.w_f + 2)    # always above the body fmt
    c = args.cin
    img = rng.standard_normal((1, args.hw, args.hw, c)) \
        .astype(np.float32)

    def k(*shape, s=0.3):
        return (rng.standard_normal(shape) * s).astype(np.float32)

    g = NetworkGraph(fmt)
    c1 = g.conv("c1", g.input_name, k(3, 3, c, args.width), relu=True)
    p1 = g.maxpool2d("p1", c1, window=2)
    c2 = g.conv("c2", p1, k(1, 1, args.width, args.width), relu=True)
    c3 = g.conv("c3", c2, k(1, 1, args.width, args.width))
    res = g.relu("r", g.add("res", c3, p1))     # skip merged in-domain
    d = g.conv("d", res, k(3, 3, args.width, args.width), hi, stride=2)
    g.output(g.avgpool2d("head", d, window=2))

    t0 = time.time()
    out = np.asarray(g.run(img))
    dt = time.time() - t0
    shapes = g.shape_plan(img.shape)
    fmts = g.format_plan()
    print(f"\nresidual_pool graph @ {args.hw}x{args.hw}x{c} "
          f"({args.fmt} body, {fmts['d']} downsample) "
          f"(bitslice-resident, incl. compile): {dt:.2f}s")
    print(f"  MACs: {g.macs(img.shape):,}  out {shapes['head']}")
    print("  nodes: " + " -> ".join(
        f"{name}[{node.kind},{fmts[name]}]"
        for name, node in g._nodes.items()))
    if args.check:
        rt = np.asarray(g.run_roundtrip(img))
        assert (out == rt).all(), "graph resident != per-layer oracle"
        print("  bit-exact vs per-layer f32-boundary oracle: OK")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fmt", default="hobflops9",
                    choices=sorted(HOBFLOPS_FORMATS))
    ap.add_argument("--hw", type=int, default=14)
    ap.add_argument("--cin", type=int, default=16)
    ap.add_argument("--width", type=int, default=16,
                    help="channel width of the stack")
    ap.add_argument("--check", action="store_true",
                    help="verify bit-exactness vs the per-layer path")
    args = ap.parse_args()
    fmt = HOBFLOPS_FORMATS[args.fmt]
    rng = np.random.default_rng(0)
    run_linear_stack(args, fmt, rng)
    run_residual_graph(args, fmt, rng)


if __name__ == "__main__":
    main()
