"""The paper's own experiment: a MobileNets feature-stage convolution
computed entirely in HOBFLOPS bitslice arithmetic (paper §3.4, Fig 5),
with the ReLU applied in the HOBFLOPS domain (one bitwise op per plane)
so data could stay bitsliced between layers.

Run: PYTHONPATH=src python examples/mobilenet_conv.py [--fmt hobflops9]
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import numpy as np

from repro.core.fpformat import HOBFLOPS_FORMATS
from repro.kernels.conv2d_bitslice.ops import hobflops_conv2d
from repro.kernels.conv2d_bitslice.ref import conv2d_f32


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fmt", default="hobflops9",
                    choices=sorted(HOBFLOPS_FORMATS))
    ap.add_argument("--hw", type=int, default=14)
    ap.add_argument("--cin", type=int, default=64)
    ap.add_argument("--cout", type=int, default=64)
    args = ap.parse_args()
    fmt = HOBFLOPS_FORMATS[args.fmt]

    rng = np.random.default_rng(0)
    # MobileNets 14x14 stage (channel count scaled for CPU wall-clock;
    # the benchmark harness sweeps the full-width version)
    img = rng.standard_normal((1, args.hw, args.hw, args.cin)) \
        .astype(np.float32)
    ker = (rng.standard_normal((1, 1, args.cin, args.cout)) * 0.2) \
        .astype(np.float32)

    t0 = time.time()
    out = np.asarray(hobflops_conv2d(img, ker, fmt=fmt, relu=True,
                                     backend="jnp"))
    dt = time.time() - t0
    f32 = np.maximum(np.asarray(conv2d_f32(img, ker)), 0.0)
    macs = args.hw * args.hw * args.cin * args.cout
    print(f"conv 1x1x{args.cin}x{args.cout} @ {args.hw}x{args.hw} "
          f"in {args.fmt} (bitslice, incl. compile): {dt:.2f}s")
    print(f"  MACs: {macs:,}")
    print(f"  rel err vs f32 conv+relu: "
          f"{np.abs(out - f32).max() / np.abs(f32).max():.4f}")
    print(f"  output sample: {out[0, 0, 0, :4]}")


if __name__ == "__main__":
    main()
