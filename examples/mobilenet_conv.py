"""The paper's own experiment, grown to a network: a MobileNets-style
feature-stage stack (3x3 conv + two pointwise convs, ReLU between)
computed end-to-end in HOBFLOPS bitslice arithmetic (paper §3.4, Fig 5).

The whole stack runs *bitslice-resident* (DESIGN.md §8): activations
are encoded to bit planes once at the input, every interior layer
boundary is a bitwise format cast + plane-domain im2col (no float32
anywhere in between), and the output is decoded once at the end.  The
same stack chained through per-layer ``hobflops_conv2d`` calls is
bit-exact — run with ``--check`` to verify.

Run: PYTHONPATH=src python examples/mobilenet_conv.py [--fmt hobflops9]
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import numpy as np

from repro.core.fpformat import HOBFLOPS_FORMATS
from repro.kernels.conv2d_bitslice.network import (ConvLayerSpec,
                                                   HobflopsNetwork)
from repro.kernels.conv2d_bitslice.ref import conv2d_f32


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fmt", default="hobflops9",
                    choices=sorted(HOBFLOPS_FORMATS))
    ap.add_argument("--hw", type=int, default=14)
    ap.add_argument("--cin", type=int, default=16)
    ap.add_argument("--width", type=int, default=16,
                    help="channel width of the stack")
    ap.add_argument("--check", action="store_true",
                    help="verify bit-exactness vs the per-layer path")
    args = ap.parse_args()
    fmt = HOBFLOPS_FORMATS[args.fmt]

    rng = np.random.default_rng(0)
    # MobileNets 14x14 stage (channel count scaled for CPU wall-clock;
    # the benchmark harness sweeps the full-width version): one 3x3
    # conv followed by two pointwise convs, ReLU after each.
    img = rng.standard_normal((1, args.hw, args.hw, args.cin)) \
        .astype(np.float32)
    shapes = [(3, 3, args.cin, args.width),
              (1, 1, args.width, args.width),
              (1, 1, args.width, args.width)]
    kernels = [(rng.standard_normal(s) * 0.2).astype(np.float32)
               for s in shapes]
    net = HobflopsNetwork([ConvLayerSpec(k, fmt, relu=True)
                           for k in kernels])

    t0 = time.time()
    out = np.asarray(net(img))
    dt = time.time() - t0

    f32 = img
    for k in kernels:
        f32 = np.maximum(np.asarray(conv2d_f32(f32, k)), 0.0)
    macs = net.macs(img.shape)
    print(f"{len(kernels)}-layer stack @ {args.hw}x{args.hw}x{args.cin} "
          f"in {args.fmt} (bitslice-resident, incl. compile): {dt:.2f}s")
    print(f"  MACs: {macs:,}  (1 activation encode, 1 decode, "
          f"{len(kernels) - 1} in-domain casts)")
    print(f"  rel err vs f32 conv+relu chain: "
          f"{np.abs(out - f32).max() / np.abs(f32).max():.4f}")
    print(f"  output sample: {out[0, 0, 0, :4]}")
    if args.check:
        rt = np.asarray(net.run_roundtrip(img))
        assert (out == rt).all(), "resident != per-layer roundtrip"
        print("  bit-exact vs per-layer decode/re-encode path: OK")


if __name__ == "__main__":
    main()
