"""The bitslice-resident multi-layer pipeline (DESIGN.md §8).

Acceptance-level checks: a >=3-layer CNN with exactly one activation
encode and one decode must be bit-exact to the chained single-layer
decode/re-encode path, and within format tolerance of the f32 chain;
the plane-domain cast must agree with the word-parallel fp_cast oracle.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import softfloat as sf
from repro.core.bitslice import BitsliceActivation, pack_planes
from repro.core.fpformat import RNE, FPFormat
from repro.kernels.conv2d_bitslice.network import (ConvLayerSpec,
                                                   HobflopsNetwork)
from repro.kernels.conv2d_bitslice.ops import (ConvWeights,
                                               cast_activations, conv_core,
                                               conv_out_hw,
                                               decode_activations,
                                               encode_activations,
                                               encode_conv_weights)
from repro.kernels.conv2d_bitslice.ref import conv2d_f32


def _rand(rng, shape, scale=1.0):
    return (rng.standard_normal(shape) * scale).astype(np.float32)


def _stack(fmt, rng, cin=4, width=8):
    """3-layer mixed stack: 3x3, pointwise, strided 3x3."""
    ks = [_rand(rng, (3, 3, cin, width), 0.4),
          _rand(rng, (1, 1, width, width), 0.4),
          _rand(rng, (3, 3, width, width), 0.4)]
    specs = [ConvLayerSpec(ks[0], fmt, relu=True),
             ConvLayerSpec(ks[1], fmt, relu=True),
             ConvLayerSpec(ks[2], fmt, stride=2, relu=False)]
    return ks, specs


def test_resident_matches_roundtrip_bit_exact():
    """The tentpole acceptance: 3 layers, single encode + single
    decode, bit-exact to the per-layer decode/re-encode path."""
    fmt = FPFormat(5, 2)   # hobflops8
    rng = np.random.default_rng(0)
    img = _rand(rng, (1, 6, 6, 4))
    _, specs = _stack(fmt, rng)
    net = HobflopsNetwork(specs)
    res = np.asarray(net(img))
    rt = np.asarray(net.run_roundtrip(img))
    assert res.shape == net.out_shape(img.shape)
    np.testing.assert_array_equal(res, rt)


def test_resident_tracks_f32_reference():
    fmt = FPFormat(5, 3)   # hobflops9
    rng = np.random.default_rng(1)
    img = _rand(rng, (1, 6, 6, 4))
    ks, specs = _stack(fmt, rng)
    net = HobflopsNetwork(specs)
    res = np.asarray(net(img))
    x = img
    for k, s in zip(ks, specs):
        x = np.asarray(conv2d_f32(x, k, stride=s.stride))
        if s.relu:
            x = np.maximum(x, 0.0)
    # 3 layers of w_f=3 quantization: loose, format-scaled tolerance
    rel = np.abs(res - x).max() / (np.abs(x).max() + 1e-9)
    assert rel < 12 * 2.0 ** -fmt.w_f, rel


def test_resident_mixed_formats():
    """Per-layer operand formats differ; boundary casts re-round."""
    rng = np.random.default_rng(2)
    img = _rand(rng, (1, 5, 5, 4))
    k1 = _rand(rng, (3, 3, 4, 8), 0.4)
    k2 = _rand(rng, (1, 1, 8, 8), 0.4)
    net = HobflopsNetwork([
        ConvLayerSpec(k1, FPFormat(5, 3), relu=True),
        ConvLayerSpec(k2, FPFormat(5, 2), relu=True)])
    res = np.asarray(net(img))
    rt = np.asarray(net.run_roundtrip(img))
    np.testing.assert_array_equal(res, rt)


def test_resident_pallas_backend_interpret():
    fmt = FPFormat(5, 2)
    rng = np.random.default_rng(3)
    img = _rand(rng, (1, 5, 5, 4))
    ks = [_rand(rng, (1, 1, 4, 32), 0.4), _rand(rng, (1, 1, 32, 32), 0.4)]
    specs = [ConvLayerSpec(k, fmt) for k in ks]
    want = np.asarray(HobflopsNetwork(specs)(img))
    got = np.asarray(HobflopsNetwork(specs, backend="pallas",
                                     interpret=True)(img))
    np.testing.assert_array_equal(got, want)


def test_no_f32_at_interior_boundaries():
    """The resident jaxpr contains exactly one encode (bitcast from f32)
    and one decode (bitcast to f32): interior boundaries never touch
    float32."""
    fmt = FPFormat(5, 2)
    rng = np.random.default_rng(4)
    img = _rand(rng, (1, 5, 5, 4))
    _, specs = _stack(fmt, rng)
    net = HobflopsNetwork(specs)
    jaxpr = jax.make_jaxpr(lambda x: net._resident(x, net.weights))(img)

    from conftest import count_primitives
    # one f32->i32 bitcast at encode + one i32->f32 at decode; the conv
    # cores and casts in between operate on int planes only.
    assert count_primitives(jaxpr.jaxpr, "bitcast_convert_type") == 2


def test_resident_stride2_valid_bit_exact():
    """stride=2 and padding=VALID through the *resident* pipeline (not
    just the per-layer path): bit-exact to the roundtrip oracle, and
    the strided net still has exactly one encode + one decode."""
    fmt = FPFormat(5, 2)
    rng = np.random.default_rng(20)
    img = _rand(rng, (2, 9, 9, 4))
    specs = [ConvLayerSpec(_rand(rng, (3, 3, 4, 8), 0.4), fmt,
                           stride=2, padding="VALID", relu=True),
             ConvLayerSpec(_rand(rng, (3, 3, 8, 8), 0.4), fmt,
                           stride=2, padding="VALID", relu=False)]
    net = HobflopsNetwork(specs)
    res = np.asarray(net(img))
    assert res.shape == net.out_shape(img.shape) == (2, 1, 1, 8)
    np.testing.assert_array_equal(res, np.asarray(net.run_roundtrip(img)))

    from conftest import count_primitives
    jaxpr = jax.make_jaxpr(lambda x: net._resident(x, net.weights))(img)
    assert count_primitives(jaxpr.jaxpr, "bitcast_convert_type") == 2


def test_cast_activations_matches_oracle():
    """Plane-domain cast == word-parallel fp_cast on the same codes."""
    src, dst = FPFormat(5, 3), FPFormat(5, 2)
    rng = np.random.default_rng(5)
    vals = _rand(rng, (64,), 4.0)
    codes = sf.encode_jnp(jnp.asarray(vals), src)
    planes = pack_planes(codes, src.nbits)[:, None, :]   # [nb, 1, Mw]
    act = BitsliceActivation(planes, src, (1, 1, 1, 64))
    out = cast_activations(act, dst)
    assert out.fmt == dst and out.shape == act.shape
    got = np.asarray(decode_activations(out)).ravel()
    want_codes = sf.fp_cast(np.asarray(codes), src, dst)
    want = sf.decode(want_codes, dst).astype(np.float32)
    np.testing.assert_array_equal(got, want)


def test_cast_activations_identity_is_noop():
    fmt = FPFormat(5, 3)
    rng = np.random.default_rng(6)
    act = encode_activations(jnp.asarray(_rand(rng, (1, 4, 4, 8))), fmt)
    assert cast_activations(act, fmt) is act


def test_conv_core_stages_compose_to_conv2d():
    """encode -> conv_core -> decode == hobflops_conv2d (the one-layer
    composition), including relu and stride."""
    from repro.kernels.conv2d_bitslice.ops import hobflops_conv2d
    fmt = FPFormat(5, 3)
    rng = np.random.default_rng(7)
    img = _rand(rng, (1, 6, 6, 4))
    ker = _rand(rng, (3, 3, 4, 8), 0.4)
    cw = encode_conv_weights(ker, fmt)
    act = encode_activations(jnp.asarray(img), fmt)
    out = conv_core(act, cw, stride=2, relu=True)
    assert out.fmt == fmt.mult_out()
    got = np.asarray(decode_activations(out))
    want = np.asarray(hobflops_conv2d(img, ker, fmt=fmt, stride=2,
                                      relu=True, backend="jnp"))
    np.testing.assert_array_equal(got, want)


def test_conv_weights_pytree_roundtrip():
    fmt = FPFormat(5, 2)
    rng = np.random.default_rng(8)
    cw = encode_conv_weights(_rand(rng, (3, 3, 4, 8)), fmt)
    leaves, treedef = jax.tree_util.tree_flatten(cw)
    assert len(leaves) == 1
    back = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(back, ConvWeights)
    assert (back.kh, back.kw, back.cin, back.cout, back.fmt) == \
        (3, 3, 4, 8, fmt)


@pytest.mark.parametrize("H,W,kh,kw,stride,padding", [
    (6, 6, 3, 3, 1, "SAME"), (6, 6, 3, 3, 2, "SAME"),
    (7, 5, 3, 3, 2, "SAME"), (7, 5, 3, 3, 2, "VALID"),
    (8, 8, 1, 1, 2, "SAME"), (5, 5, 3, 3, 1, "VALID"),
])
def test_conv_out_hw_matches_im2col(H, W, kh, kw, stride, padding):
    from repro.kernels.conv2d_bitslice.ops import im2col
    x = jnp.zeros((1, H, W, 2), jnp.float32)
    pat = im2col(x, kh, kw, stride, padding)
    assert (pat.shape[1], pat.shape[2]) == \
        conv_out_hw(H, W, kh, kw, stride, padding)
