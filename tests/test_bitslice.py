"""Bit-plane transform properties (hypothesis) and codegen equivalence."""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import softfloat as sf
from repro.core.bitslice import (pack_planes, pack_planes_np,
                                 unpack_planes, unpack_planes_np)
from repro.core.codegen import emit_source, eval_netlist, make_jax_fn
from repro.core.fpcore import build_add
from repro.core.fpformat import RNE, FPFormat
from repro.core.opt import CELL_LIBS, tech_map


@given(st.integers(1, 20),
       st.lists(st.integers(0, 2 ** 20 - 1), min_size=1, max_size=300))
@settings(max_examples=50, deadline=None)
def test_pack_unpack_roundtrip_np(nbits, values):
    codes = np.array(values, dtype=np.int64) & ((1 << nbits) - 1)
    planes = pack_planes_np(codes, nbits)
    back = unpack_planes_np(planes, len(codes))
    np.testing.assert_array_equal(back, codes)


@given(st.integers(1, 16), st.integers(1, 4))
@settings(max_examples=20, deadline=None)
def test_pack_unpack_roundtrip_jnp(nbits, nwords):
    import jax.numpy as jnp
    rng = np.random.default_rng(nbits * 31 + nwords)
    codes = rng.integers(0, 1 << nbits, nwords * 32).astype(np.int32)
    planes = pack_planes(jnp.asarray(codes), nbits)
    assert planes.shape == (nbits, nwords)
    back = np.asarray(unpack_planes(planes))
    np.testing.assert_array_equal(back, codes)


def test_pack_planes_pads_ragged_lane_dim():
    """pack_planes zero-pads N to a lane-word multiple internally
    (mirroring pack_planes_np) instead of asserting."""
    import jax.numpy as jnp
    rng = np.random.default_rng(3)
    for n in (1, 31, 33, 50):
        codes = rng.integers(0, 1 << 7, n).astype(np.int32)
        planes = pack_planes(jnp.asarray(codes), 7)
        assert planes.shape == (7, -(-n // 32))
        back = np.asarray(unpack_planes(planes))[:n]
        np.testing.assert_array_equal(back, codes)


def test_jax_fn_matches_interpreter():
    import jax.numpy as jnp
    fmt = FPFormat(4, 3)
    g = tech_map(build_add(fmt, RNE), CELL_LIBS["tpu_vpu"]())
    rng = np.random.default_rng(7)
    xs = sf.encode(rng.standard_normal(256), fmt)
    ys = sf.encode(rng.standard_normal(256), fmt)
    # 32-bit lane words (jax x32 mode truncates int64)
    px = pack_planes_np(xs, fmt.nbits, lane_bits=32).astype(
        np.uint32).view(np.int32)
    py = pack_planes_np(ys, fmt.nbits, lane_bits=32).astype(
        np.uint32).view(np.int32)
    out_np = eval_netlist(g, {"x": px, "y": py})["out"]
    fn = make_jax_fn(g)
    out_jx = np.asarray(fn(x=jnp.asarray(px), y=jnp.asarray(py))["out"])
    np.testing.assert_array_equal(out_np, out_jx)


def test_emit_source_is_python_ish():
    fmt = FPFormat(3, 2)
    g = build_add(fmt, RNE)
    src = emit_source(g, "adder")
    assert src.startswith("def adder(")
    assert "return {" in src
    # one line per live gate
    assert len(src.splitlines()) > g.live_gate_count()
