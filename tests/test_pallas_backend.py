"""Fused Pallas netlist compiler backend (DESIGN.md §12).

Differential coverage for ``repro.core.pallas_backend``: the lowered
register-file emission must be bit-identical to the ``eval_netlist``
oracle on every format x rounding (exhaustive on the smallest format,
randomized wide-lane elsewhere), the register file must fail loudly on
overflow, and a fused conv must emit exactly one ``pallas_call``.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.codegen import eval_netlist
from repro.core.fpcore import build_mac_chain
from repro.core.fpformat import HOBFLOPS_FORMATS, RNE, RTZ
from repro.core.opt import optimize_mapped
from repro.core.pallas_backend import (STACK_MAX_DEFAULT,
                                       RegisterFileOverflow,
                                       fused_chain_k, lower_netlist)
from repro.kernels.bitslice_mac.ops import hobflops_matmul
from repro.kernels.conv2d_bitslice.network import NetworkGraph
from repro.kernels.conv2d_bitslice.ops import hobflops_conv2d

F8 = HOBFLOPS_FORMATS["hobflops8"]
F16 = HOBFLOPS_FORMATS["hobflops16"]


def _mac_graph(fmt, k=1, rounding=RNE, extended=False):
    return optimize_mapped(build_mac_chain(fmt, k, extended, rounding),
                           "tpu_vpu")


def _rand_chain_inputs(graph, rng, P=4, Mw=2):
    """Random lane-resolved planes for every input bus of a MAC chain:
    x buses get independent per-lane bits, y buses 0/-1 broadcast
    masks, acc full random planes — the real kernel's value classes."""
    inputs = {}
    for name, bus in graph.inputs.items():
        w = len(bus)
        if name.startswith("y"):
            v = -rng.integers(0, 2, (w, P, 1)).astype(np.int64)
            inputs[name] = np.broadcast_to(v, (w, P, Mw))
        else:
            inputs[name] = rng.integers(-2**31, 2**31, (w, P, Mw),
                                        dtype=np.int64)
    return {k: v.astype(np.int32) for k, v in inputs.items()}


# ---------------------------------------------------------------------------
# Emitter vs eval_netlist oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", ["hobflops8", "hobflops9",
                                  "hobflops16"])
@pytest.mark.parametrize("rounding", [RNE, RTZ])
def test_lowered_matches_eval_netlist(name, rounding):
    """Randomized wide-lane differential: the lowered register-file
    program is bit-identical to the numpy interpreter for every output
    plane.  hobflops16's 19-plane out bus exercises the one-hot
    assembly, hobflops8/9 the plain-stack path."""
    fmt = HOBFLOPS_FORMATS[name]
    g = _mac_graph(fmt, k=2, rounding=rounding)
    lowered = lower_netlist(g)
    rng = np.random.default_rng(hash((name, rounding)) % 2**32)
    inputs = _rand_chain_inputs(g, rng)
    want = eval_netlist(g, inputs)
    got = jax.jit(lambda kw: lowered(**kw))(
        {k: jnp.asarray(v) for k, v in inputs.items()})
    for bus in want:
        assert np.array_equal(np.asarray(got[bus]), want[bus]), bus


def test_lowered_exhaustive_small_format():
    """Exhaustive hobflops8 sweep: every (x code, y code) pair runs
    through one lowered MAC step via broadcasting — x codes packed
    along lanes, y codes as row masks — and must match the oracle on
    all 2^16 pairs at both roundings."""
    n = 1 << F8.nbits
    codes = np.arange(n, dtype=np.int64)
    bits = (codes[:, None] >> np.arange(F8.nbits)) & 1      # [n, nbits]
    # x: all n codes along int32 lanes -> [nbits, 1, n/32]
    xp = np.zeros((F8.nbits, 1, n // 32), np.int64)
    for c in range(n):
        xp[:, 0, c // 32] |= bits[c] << (c % 32)
    # y: all n codes as per-row 0/-1 masks -> [nbits, n, 1]
    yp = -bits.T[:, :, None]
    for rounding in (RNE, RTZ):
        g = _mac_graph(F8, k=1, rounding=rounding)
        lowered = lower_netlist(g)
        inputs = {"x0": xp.astype(np.int32), "y0": yp.astype(np.int32),
                  "acc": np.zeros((len(g.inputs["acc"]), n, n // 32),
                                  np.int32)}
        want = eval_netlist(g, inputs)["out"]
        got = np.asarray(jax.jit(lambda kw: lowered(**kw)["out"])(
            {k: jnp.asarray(v) for k, v in inputs.items()}))
        assert np.array_equal(np.broadcast_to(got, want.shape), want)


def test_onehot_assembly_used_and_bit_exact():
    """Forcing ``stack_max`` below the bus width switches hobflops8 to
    the one-hot or-tree assembly; values must not change."""
    g = _mac_graph(F8)
    rng = np.random.default_rng(3)
    inputs = _rand_chain_inputs(g, rng)
    jinp = {k: jnp.asarray(v) for k, v in inputs.items()}
    plain = lower_netlist(g)(**jinp)["out"]
    forced = lower_netlist(g, stack_max=2)(**jinp)["out"]
    assert np.array_equal(np.asarray(plain), np.asarray(forced))


# ---------------------------------------------------------------------------
# Register file
# ---------------------------------------------------------------------------
def test_register_file_overflow_fails_loudly():
    """A file smaller than the schedule's peak must raise at lowering
    time — never spill silently or corrupt lanes; an exact-size file
    still evaluates bit-identically to the oracle."""
    g = _mac_graph(F8)
    nslots = lower_netlist(g).nslots
    with pytest.raises(RegisterFileOverflow) as ei:
        lower_netlist(g, regfile_size=nslots - 1)
    assert ei.value.need == nslots and ei.value.have == nslots - 1
    exact = lower_netlist(g, regfile_size=nslots)
    rng = np.random.default_rng(4)
    inputs = _rand_chain_inputs(g, rng)
    want = eval_netlist(g, inputs)["out"]
    got = np.asarray(exact(**{k: jnp.asarray(v)
                              for k, v in inputs.items()})["out"])
    assert np.array_equal(np.broadcast_to(got, want.shape), want)


# ---------------------------------------------------------------------------
# Backend wiring: matmul / conv / network
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", ["hobflops8", "hobflops16"])
@pytest.mark.parametrize("rounding", [RNE, RTZ])
def test_fused_matmul_matches_jnp(name, rounding):
    fmt = HOBFLOPS_FORMATS[name]
    rng = np.random.default_rng(5)
    i = rng.standard_normal((8, 12)).astype(np.float32)
    w = rng.standard_normal((12, 40)).astype(np.float32)
    a = hobflops_matmul(i, w, fmt=fmt, rounding=rounding, backend="jnp",
                        c_unroll=1)
    b = hobflops_matmul(i, w, fmt=fmt, rounding=rounding, c_unroll=1,
                        backend="pallas_fused", interpret=True)
    assert np.array_equal(np.asarray(a), np.asarray(b))


def test_fused_conv_relu_epilogue_matches_jnp():
    """The in-kernel ReLU epilogue (applied only on the final C grid
    step) must agree with the post-hoc hobflops_relu_planes pass."""
    rng = np.random.default_rng(6)
    img = rng.standard_normal((1, 6, 6, 4)).astype(np.float32)
    ker = (rng.standard_normal((3, 3, 4, 8)) * 0.3).astype(np.float32)
    for relu in (False, True):
        a = hobflops_conv2d(img, ker, fmt=F8, relu=relu, backend="jnp")
        b = hobflops_conv2d(img, ker, fmt=F8, relu=relu,
                            backend="pallas_fused", interpret=True)
        assert np.array_equal(np.asarray(a), np.asarray(b)), relu


def _count_pallas_calls(jaxpr, n=0):
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            n += 1
        for v in eqn.params.values():
            if hasattr(v, "eqns"):
                n = _count_pallas_calls(v, n)
            elif hasattr(v, "jaxpr"):
                n = _count_pallas_calls(v.jaxpr, n)
    return n


def test_fused_conv_emits_single_pallas_call():
    """The acceptance pin: a fused conv (MAC chain + ReLU epilogue) is
    ONE pallas_call in the jaxpr, not hundreds of elementwise ops."""
    rng = np.random.default_rng(7)
    img = rng.standard_normal((1, 4, 4, 4)).astype(np.float32)
    ker = (rng.standard_normal((3, 3, 4, 8)) * 0.3).astype(np.float32)
    jx = jax.make_jaxpr(lambda x, k: hobflops_conv2d(
        x, k, fmt=F8, relu=True, backend="pallas_fused",
        interpret=True))(img, ker)
    assert _count_pallas_calls(jx.jaxpr) == 1


def test_fused_chain_k_policy():
    """Wide out buses (hobflops16: 19 planes) clamp the fused chain to
    k=1 — deeper chains compile superlinearly for no duplication win —
    while narrow formats keep the requested depth."""
    assert F16.mult_out(False).nbits > STACK_MAX_DEFAULT
    assert fused_chain_k(F16, False, 4) == 1
    assert F8.mult_out(False).nbits <= STACK_MAX_DEFAULT
    assert fused_chain_k(F8, False, 4) == 4


def test_fused_network_graph_end_to_end():
    """backend='pallas_fused' selected at NetworkGraph construction
    flows through the resident interpreter and changes signature()
    (so RunnerCache keys can never collide across backends)."""
    rng = np.random.default_rng(8)
    img = rng.standard_normal((1, 6, 6, 4)).astype(np.float32)
    ker = (rng.standard_normal((3, 3, 4, 8)) * 0.3).astype(np.float32)

    def build(backend, interpret=False):
        g = NetworkGraph(F8, backend=backend, interpret=interpret)
        y = g.conv("c1", g.input_name, ker, relu=True,
                   blocks={"c_unroll": 2})
        g.output(g.cast("cast", y, F8))
        return g

    ref = build("jnp")
    fused = build("pallas_fused", interpret=True)
    a = ref.run(img)
    b = fused.run(img)
    assert np.array_equal(np.asarray(a), np.asarray(b))
    assert ref.signature() != fused.signature()
