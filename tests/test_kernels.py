"""Pallas kernels vs pure oracles: shape/dtype/format sweeps.

Each kernel runs in interpret mode (the kernel body executes as real
jax ops on CPU) and must agree with the sequential code-level oracle —
for the bitslice MAC, bit-exactly."""
import numpy as np
import pytest

from repro.core import softfloat as sf
from repro.core.fpformat import RNE, RTZ, FPFormat, StorageFormat
from repro.kernels.bitslice_mac.ops import hobflops_matmul
from repro.kernels.bitslice_mac.ref import hobflops_matmul_f64
from repro.kernels.conv2d_bitslice.ops import hobflops_conv2d, im2col
from repro.kernels.conv2d_bitslice.ref import (conv2d_f32,
                                               hobflops_conv2d_ref)
from repro.kernels.dequant_matmul.ops import dequant_matmul, pack_weights
from repro.kernels.dequant_matmul.ref import dequant_matmul_ref
from repro.quant.storage import dequantize, quantize


def _rand(rng, shape, scale=1.0):
    return (rng.standard_normal(shape) * scale).astype(np.float32)


@pytest.mark.parametrize("fmt,extended,rounding", [
    (FPFormat(5, 2), False, RNE),      # hobflops8
    (FPFormat(5, 3), False, RNE),      # hobflops9
    (FPFormat(5, 3), True, RNE),       # hobflops9e
    (FPFormat(4, 3), False, RNE),      # ieee8
    (FPFormat(5, 3), False, RTZ),
])
def test_bitslice_mac_formats(fmt, extended, rounding):
    rng = np.random.default_rng(hash((fmt.w_e, fmt.w_f, extended)) % 99)
    P, C, M = 4, 8, 32
    i, w = _rand(rng, (P, C)), _rand(rng, (C, M))
    want = hobflops_matmul_f64(i, w, fmt, extended, rounding)
    got = np.asarray(hobflops_matmul(
        i, w, fmt=fmt, extended=extended, rounding=rounding,
        backend="pallas", interpret=True, p_block=4, m_block=1,
        c_block=8))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("P,C,M", [(1, 1, 32), (3, 5, 32), (8, 16, 64),
                                   (16, 32, 96)])
def test_bitslice_mac_shapes(P, C, M):
    fmt = FPFormat(5, 3)
    rng = np.random.default_rng(P * 100 + C)
    i, w = _rand(rng, (P, C)), _rand(rng, (C, M))
    want = hobflops_matmul_f64(i, w, fmt)
    got_j = np.asarray(hobflops_matmul(i, w, fmt=fmt, backend="jnp"))
    got_p = np.asarray(hobflops_matmul(
        i, w, fmt=fmt, backend="pallas", interpret=True,
        p_block=min(4, P), m_block=1, c_block=min(8, C)))
    np.testing.assert_array_equal(got_j, want)
    np.testing.assert_array_equal(got_p, want)


def test_bitslice_mac_zero_identity():
    """Zero-padding is the MAC identity (paper's tiling assumption)."""
    fmt = FPFormat(5, 3)
    rng = np.random.default_rng(0)
    i, w = _rand(rng, (4, 8)), _rand(rng, (8, 32))
    base = np.asarray(hobflops_matmul(i, w, fmt=fmt, backend="jnp"))
    ip = np.concatenate([i, np.zeros((4, 8), np.float32)], axis=1)
    wp = np.concatenate([w, np.zeros((8, 32), np.float32)], axis=0)
    padded = np.asarray(hobflops_matmul(ip, wp, fmt=fmt, backend="jnp"))
    np.testing.assert_array_equal(base, padded)


def test_bitslice_mac_accuracy_tracks_precision():
    rng = np.random.default_rng(5)
    i, w = _rand(rng, (8, 16)), _rand(rng, (16, 32))
    exact = i.astype(np.float64) @ w.astype(np.float64)
    errs = []
    for wf in (2, 4, 7, 10):
        fmt = FPFormat(5, wf)
        got = hobflops_matmul_f64(i, w, fmt)
        errs.append(np.abs(got - exact).max())
    assert errs[0] > errs[1] > errs[2] > errs[3]


@pytest.mark.parametrize("sfmt", [StorageFormat(5, 2), StorageFormat(5, 3),
                                  StorageFormat(4, 3), StorageFormat(8, 7)])
@pytest.mark.parametrize("MKN", [(8, 32, 64), (16, 64, 128)])
def test_dequant_matmul(sfmt, MKN):
    M, K, N = MKN
    rng = np.random.default_rng(M * K)
    x, w = _rand(rng, (M, K)), _rand(rng, (K, N))
    qt = pack_weights(w, sfmt)
    want = np.asarray(dequant_matmul_ref(x, qt.data, qt.scale, sfmt, N))
    got = np.asarray(dequant_matmul(x, qt, backend="pallas",
                                    interpret=True, bm=8, bn=32, bk=16))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_dequant_matmul_footprint():
    w = np.random.default_rng(0).standard_normal((64, 128)).astype(
        np.float32)
    sfmt = StorageFormat(5, 3)   # 9 bits/weight
    qt = pack_weights(w, sfmt)
    assert qt.data.size * 4 == 64 * 128 * 9 // 8  # true bit packing


@pytest.mark.parametrize("stride,padding", [(1, "SAME"), (2, "SAME"),
                                            (1, "VALID")])
def test_im2col_matches_lax_conv(stride, padding):
    rng = np.random.default_rng(2)
    img = _rand(rng, (2, 8, 8, 4))
    ker = _rand(rng, (3, 3, 4, 8), 0.4)
    pat = np.asarray(im2col(img, 3, 3, stride, padding))
    got = pat.reshape(-1, 36) @ ker.reshape(36, 8)
    want = conv2d_f32(img, ker, stride, padding).reshape(-1, 8)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("relu", [False, True])
def test_hobflops_conv2d(relu):
    fmt = FPFormat(5, 3)
    rng = np.random.default_rng(4)
    img = _rand(rng, (1, 5, 5, 4))
    ker = _rand(rng, (3, 3, 4, 32), 0.4)
    got = np.asarray(hobflops_conv2d(img, ker, fmt=fmt, relu=relu,
                                     backend="jnp"))
    want = hobflops_conv2d_ref(img, ker, fmt, relu=relu)
    np.testing.assert_array_equal(got, want)


def test_bitslice_mac_c_unroll_chain():
    """Chained-channel kernel (c_unroll > 1) stays bit-exact, including
    when c_unroll does not divide C (clamping / padding paths)."""
    fmt = FPFormat(5, 3)
    rng = np.random.default_rng(7)
    P, C, M = 8, 12, 64
    i, w = _rand(rng, (P, C)), _rand(rng, (C, M))
    want = hobflops_matmul_f64(i, w, fmt)
    for c_unroll in (1, 2, 4, 5):
        got_j = np.asarray(hobflops_matmul(
            i, w, fmt=fmt, backend="jnp", c_unroll=c_unroll))
        np.testing.assert_array_equal(got_j, want)
    got_p = np.asarray(hobflops_matmul(
        i, w, fmt=fmt, backend="pallas", interpret=True, p_block=4,
        m_block=2, c_block=4, c_unroll=4))
    np.testing.assert_array_equal(got_p, want)


def test_hobflops_conv2d_pallas_tiled():
    """Acceptance: the Pallas path with real tiling (M > 32 so the M
    grid axis is exercised with m_block > 1, C > c_unroll so the chain
    loop runs multiple steps) is bit-exact vs the jnp reference."""
    fmt = FPFormat(5, 2)
    rng = np.random.default_rng(11)
    img = _rand(rng, (1, 6, 6, 5))
    ker = _rand(rng, (3, 3, 5, 48), 0.4)   # K = 45 > c_unroll, M = 48 > 32
    want = np.asarray(hobflops_conv2d(img, ker, fmt=fmt, backend="jnp"))
    got = np.asarray(hobflops_conv2d(img, ker, fmt=fmt, backend="pallas",
                                     interpret=True))
    np.testing.assert_array_equal(got, want)


def test_derive_blocks():
    from repro.kernels.conv2d_bitslice.ops import derive_blocks
    blk = derive_blocks(36, 45, 48)
    assert blk["p_block"] == 8 and blk["m_block"] == 2
    assert blk["c_block"] == 45 and blk["c_block"] % blk["c_unroll"] == 0
    # explicit overrides win but are still clamped to the problem
    blk = derive_blocks(4, 8, 32, p_block=16, m_block=4, c_unroll=3)
    assert blk["p_block"] == 4 and blk["m_block"] == 1
    assert blk["c_block"] % blk["c_unroll"] == 0


def test_matmul_pre_encoded_weights():
    """hobflops_matmul(w_planes=...) == hobflops_matmul(w_f32) — static
    weights encoded once, bit-exact, including non-lane-multiple M."""
    from repro.kernels.bitslice_mac.ops import encode_weight_planes
    fmt = FPFormat(5, 3)
    rng = np.random.default_rng(21)
    P, C, M = 5, 12, 48
    i, w = _rand(rng, (P, C)), _rand(rng, (C, M))
    want = np.asarray(hobflops_matmul(i, w, fmt=fmt, backend="jnp"))
    wp = encode_weight_planes(w, fmt)
    got = np.asarray(hobflops_matmul(i, fmt=fmt, w_planes=wp, cout=M,
                                     backend="jnp"))
    np.testing.assert_array_equal(got, want)
    got_p = np.asarray(hobflops_matmul(
        i, fmt=fmt, w_planes=wp, cout=M, backend="pallas",
        interpret=True, p_block=4, m_block=1, c_block=4))
    np.testing.assert_array_equal(got_p, want)


def test_conv2d_pre_encoded_weights():
    """hobflops_conv2d accepts a ConvWeights in place of f32 kernels."""
    from repro.kernels.conv2d_bitslice.ops import encode_conv_weights
    fmt = FPFormat(5, 2)
    rng = np.random.default_rng(22)
    img = _rand(rng, (1, 5, 5, 4))
    ker = _rand(rng, (3, 3, 4, 8), 0.4)
    want = np.asarray(hobflops_conv2d(img, ker, fmt=fmt, relu=True,
                                      backend="jnp"))
    cw = encode_conv_weights(ker, fmt)
    got = np.asarray(hobflops_conv2d(img, cw, fmt=fmt, relu=True,
                                     backend="jnp"))
    np.testing.assert_array_equal(got, want)


def test_tune_conv_blocks_dedupe_uses_strided_patch_count():
    """Candidates that clamp to the same launch config for the *actual*
    strided Ho*Wo patch count must dedupe to one timed entry (the seed
    keyed on the unstrided B*H*W, splitting them)."""
    from repro.kernels.conv2d_bitslice.ops import tune_conv_blocks
    fmt = FPFormat(5, 2)
    rng = np.random.default_rng(23)
    img = _rand(rng, (1, 8, 8, 4))
    ker = _rand(rng, (1, 1, 4, 32), 0.4)
    # stride 2 -> P = 16; p_block 16 and 32 both clamp to 16.
    best, results = tune_conv_blocks(
        img, ker, fmt=fmt, stride=2, backend="jnp", iters=1,
        candidates=[{"p_block": 16}, {"p_block": 32}])
    assert len(results) == 1, results
    (key,) = results
    assert dict(key)["p_block"] == 16


def test_hobflops_relu_is_bitwise():
    """ReLU in the bitslice domain == ReLU on decoded values."""
    import jax.numpy as jnp
    from repro.core.bitslice import pack_planes, unpack_planes
    from repro.kernels.conv2d_bitslice.ops import hobflops_relu_planes
    fmt = FPFormat(5, 4)
    rng = np.random.default_rng(9)
    vals = rng.standard_normal(64).astype(np.float32)
    codes = sf.encode_jnp(jnp.asarray(vals), fmt)
    planes = pack_planes(codes, fmt.nbits)
    relu_planes = hobflops_relu_planes(planes, fmt)
    back = np.asarray(sf.decode_jnp(unpack_planes(relu_planes), fmt))
    want = np.asarray(sf.decode_jnp(codes, fmt))
    want = np.where(want <= 0, 0.0, want)
    np.testing.assert_array_equal(back, want)
