"""Gate-level circuits vs the softfloat oracle — exhaustive for small
formats — plus tech-mapping equivalence (the Yosys-SAT analogue) and
gate-count regression guards."""
import numpy as np
import pytest

from repro.core import softfloat as sf
from repro.core.bitslice import pack_planes_np, unpack_planes_np
from repro.core.circuit import Graph
from repro.core.codegen import eval_netlist
from repro.core.fpcore import (build_add, build_cast, build_mac,
                               build_mac_chain, build_max, build_mul,
                               build_scale)
from repro.core.fpformat import RNE, RTZ, FPFormat
from repro.core.opt import (CELL_LIBS, absorb_andn, const_prop,
                            lib_gate_count, optimize_mapped, sweep,
                            tech_map)

from test_softfloat import canonical_codes


def run_netlist(g, inputs_codes: dict, widths: dict):
    planes = {name: pack_planes_np(codes, widths[name])
              for name, codes in inputs_codes.items()}
    out = eval_netlist(g, planes)["out"]
    n = len(next(iter(inputs_codes.values())))
    return unpack_planes_np(out, n)


@pytest.mark.parametrize("rounding", [RNE, RTZ])
@pytest.mark.parametrize("extended", [False, True])
def test_mul_exhaustive(rounding, extended):
    fmt = FPFormat(3, 2)
    fmt_out = fmt.mult_out(extended)
    xs = canonical_codes(fmt)
    X, Y = np.repeat(xs, len(xs)), np.tile(xs, len(xs))
    g = build_mul(fmt, fmt_out, rounding)
    got = run_netlist(g, {"x": X, "y": Y},
                      {"x": fmt.nbits, "y": fmt.nbits})
    want = sf.fp_mul(X, Y, fmt, fmt_out, rounding)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("rounding", [RNE, RTZ])
@pytest.mark.parametrize("fmt", [FPFormat(3, 3), FPFormat(4, 2)])
def test_add_exhaustive(rounding, fmt):
    xs = canonical_codes(fmt)
    X, Y = np.repeat(xs, len(xs)), np.tile(xs, len(xs))
    g = build_add(fmt, rounding)
    got = run_netlist(g, {"x": X, "y": Y},
                      {"x": fmt.nbits, "y": fmt.nbits})
    want = sf.fp_add(X, Y, fmt, rounding)
    np.testing.assert_array_equal(got, want)


def test_mac_random():
    fmt = FPFormat(5, 2)   # hobflops8
    fmt_out = fmt.mult_out()
    rng = np.random.default_rng(0)
    n = 4096
    X = canonical_codes(fmt)[rng.integers(0, 2 ** fmt.nbits - 300, n) % 261]
    Y = canonical_codes(fmt)[rng.integers(0, 261, n)]
    A = canonical_codes(fmt_out)[rng.integers(
        0, len(canonical_codes(fmt_out)), n)]
    g = build_mac(fmt)
    got = run_netlist(g, {"x": X, "y": Y, "acc": A},
                      {"x": fmt.nbits, "y": fmt.nbits,
                       "acc": fmt_out.nbits})
    want = sf.fp_mac(X, Y, A, fmt, fmt_out)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("lib", ["tpu_vpu", "avx2", "neon", "avx512"])
def test_tech_map_preserves_semantics(lib):
    fmt = FPFormat(3, 2)
    fmt_out = fmt.mult_out()
    xs = canonical_codes(fmt)
    X, Y = np.repeat(xs, len(xs)), np.tile(xs, len(xs))
    g = build_mul(fmt, fmt_out, RNE)
    mapped = tech_map(g, CELL_LIBS[lib]())
    got = run_netlist(mapped, {"x": X, "y": Y},
                      {"x": fmt.nbits, "y": fmt.nbits})
    want = run_netlist(g, {"x": X, "y": Y},
                       {"x": fmt.nbits, "y": fmt.nbits})
    np.testing.assert_array_equal(got, want)


def test_lib_ordering_matches_paper():
    """Paper: AVX512 (ternary LUT) < Neon (SEL) < AVX2 (2-input) in
    bitwise op count for the same MAC."""
    fmt = FPFormat(5, 2)
    g = build_mac(fmt)
    gates = {lib: tech_map(g, CELL_LIBS[lib]()).live_gate_count()
             for lib in ("avx2", "neon", "avx512")}
    assert gates["avx512"] < gates["neon"] < gates["avx2"]


def test_rtz_smaller_than_rne():
    """Paper §4: round-towards-zero removes the rounding adder."""
    fmt = FPFormat(5, 3)
    rne = build_mac(fmt, rounding=RNE).live_gate_count()
    rtz = build_mac(fmt, rounding=RTZ).live_gate_count()
    assert rtz < rne


def test_gate_count_monotone_in_precision():
    g8 = build_mac(FPFormat(5, 2)).live_gate_count()
    g12 = build_mac(FPFormat(5, 6)).live_gate_count()
    g16 = build_mac(FPFormat(5, 10)).live_gate_count()
    assert g8 < g12 < g16


# ---------------------------------------------------------------------------
# Format cast (the bitslice-resident layer boundary)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("rounding", [RNE, RTZ])
@pytest.mark.parametrize("fmt_in,fmt_out", [
    (FPFormat(2, 1), FPFormat(2, 1)),      # identity
    (FPFormat(2, 3), FPFormat(2, 1)),      # e2m1 mult_out -> storage
    (FPFormat(3, 3), FPFormat(3, 2)),      # accumulator -> operand
    (FPFormat(2, 1), FPFormat(3, 3)),      # widening (exact)
    (FPFormat(4, 3), FPFormat(3, 2)),      # cross-w_e narrowing
])
def test_cast_exhaustive(fmt_in, fmt_out, rounding):
    """build_cast == softfloat.fp_cast over EVERY canonical code, and
    fp_cast == encode(decode(x)) (no double rounding: decode is exact in
    f64), for small formats."""
    xs = canonical_codes(fmt_in)
    g = build_cast(fmt_in, fmt_out, rounding)
    got = run_netlist(g, {"x": xs}, {"x": fmt_in.nbits})
    want = sf.fp_cast(xs, fmt_in, fmt_out, rounding)
    np.testing.assert_array_equal(got, want)
    roundtrip = sf.encode(sf.decode(xs, fmt_in), fmt_out, rounding)
    np.testing.assert_array_equal(want, roundtrip)


@pytest.mark.parametrize("lib", ["tpu_vpu", "avx2", "neon", "avx512"])
def test_cast_optimize_mapped_preserves_semantics(lib):
    fmt_in, fmt_out = FPFormat(3, 3), FPFormat(3, 2)
    xs = canonical_codes(fmt_in)
    g = build_cast(fmt_in, fmt_out, RNE)
    want = run_netlist(g, {"x": xs}, {"x": fmt_in.nbits})
    opt = optimize_mapped(g, lib)
    got = run_netlist(opt, {"x": xs}, {"x": fmt_in.nbits})
    np.testing.assert_array_equal(got, want)


def test_cast_is_cheap():
    """The boundary cast must be small change next to a MAC — that is
    the whole point of staying bitslice-resident."""
    fmt = FPFormat(5, 3)                   # hobflops9
    cast = build_cast(fmt.mult_out(), fmt).live_gate_count()
    mac = build_mac(fmt).live_gate_count()
    assert cast * 5 < mac, (cast, mac)


# ---------------------------------------------------------------------------
# Max / power-of-two scale (the graph runner's pooling netlists)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("fmt", [FPFormat(3, 2), FPFormat(4, 2),
                                 FPFormat(3, 3)])
def test_max_exhaustive(fmt):
    """build_max == softfloat.fp_max over every canonical pair, and
    fp_max == float max on the decoded values wherever neither operand
    is NaN (the FP-semantics sanity anchor)."""
    xs = canonical_codes(fmt)
    X, Y = np.repeat(xs, len(xs)), np.tile(xs, len(xs))
    g = build_max(fmt)
    got = run_netlist(g, {"x": X, "y": Y},
                      {"x": fmt.nbits, "y": fmt.nbits})
    want = sf.fp_max(X, Y, fmt)
    np.testing.assert_array_equal(got, want)
    dx, dy = sf.decode(X, fmt), sf.decode(Y, fmt)
    ok = ~(np.isnan(dx) | np.isnan(dy))
    np.testing.assert_array_equal(sf.decode(want, fmt)[ok],
                                  np.maximum(dx, dy)[ok])


def test_max_nan_and_signed_zero():
    fmt = FPFormat(3, 2)
    nan = sf.pack(3, 0, 0, 0, fmt)
    pz, nz = sf.pack(0, 0, 0, 0, fmt), sf.pack(0, 1, 0, 0, fmt)
    one = sf.encode(1.0, fmt)
    assert sf.fp_max(nan, one, fmt) == nan
    assert sf.fp_max(one, nan, fmt) == nan
    assert sf.fp_max(pz, nz, fmt) == pz
    assert sf.fp_max(nz, pz, fmt) == pz
    assert sf.fp_max(nz, nz, fmt) == nz


@pytest.mark.parametrize("fmt,k", [
    (FPFormat(3, 2), 0), (FPFormat(3, 2), 2), (FPFormat(4, 2), 1),
    (FPFormat(3, 3), 3),
    (FPFormat(2, 2), 4),    # k > emax: every normal must flush to +0
    (FPFormat(2, 1), 9),    # k >> 2**w_e (would truncate in const_bus)
])
def test_scale_exhaustive(fmt, k):
    """build_scale == softfloat.fp_scale over every canonical code, and
    fp_scale == encode(decode(x) * 2**-k) (scaling is exact, so there
    is no rounding to disagree on)."""
    xs = canonical_codes(fmt)
    g = build_scale(fmt, k)
    got = run_netlist(g, {"x": xs}, {"x": fmt.nbits})
    want = sf.fp_scale(xs, k, fmt)
    np.testing.assert_array_equal(got, want)
    roundtrip = sf.encode(sf.decode(xs, fmt) * 2.0 ** -k, fmt)
    np.testing.assert_array_equal(want, roundtrip)


@pytest.mark.parametrize("lib", ["tpu_vpu", "avx2", "neon", "avx512"])
def test_max_scale_optimize_mapped_preserves_semantics(lib):
    fmt = FPFormat(3, 3)
    xs = canonical_codes(fmt)
    X, Y = np.repeat(xs, len(xs)), np.tile(xs, len(xs))
    gm = build_max(fmt)
    want = run_netlist(gm, {"x": X, "y": Y},
                       {"x": fmt.nbits, "y": fmt.nbits})
    got = run_netlist(optimize_mapped(gm, lib), {"x": X, "y": Y},
                      {"x": fmt.nbits, "y": fmt.nbits})
    np.testing.assert_array_equal(got, want)
    gs = build_scale(fmt, 2)
    want = run_netlist(gs, {"x": xs}, {"x": fmt.nbits})
    got = run_netlist(optimize_mapped(gs, lib), {"x": xs},
                      {"x": fmt.nbits})
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# Fused K-step MAC chain
# ---------------------------------------------------------------------------
def _mac_sequential(fmt, xs, ys, acc, extended=False, rounding=RNE):
    """k sequential build_mac netlist applications (the chain oracle)."""
    fmt_out = fmt.mult_out(extended)
    g = build_mac(fmt, extended, rounding)
    cur = acc
    for x, y in zip(xs, ys):
        cur = run_netlist(g, {"x": x, "y": y, "acc": cur},
                          {"x": fmt.nbits, "y": fmt.nbits,
                           "acc": fmt_out.nbits})
    return cur


def _run_chain(fmt, k, xs, ys, acc, extended=False, rounding=RNE):
    fmt_out = fmt.mult_out(extended)
    g = build_mac_chain(fmt, k, extended, rounding)
    codes = {f"x{i}": xs[i] for i in range(k)}
    codes |= {f"y{i}": ys[i] for i in range(k)}
    codes["acc"] = acc
    widths = {n: fmt.nbits for n in codes}
    widths["acc"] = fmt_out.nbits
    return run_netlist(g, codes, widths)


def test_mac_chain_exhaustive_small():
    """k=2 chain == 2 sequential MACs over EVERY canonical operand
    combination of the smallest legal format (e2m1)."""
    fmt = FPFormat(2, 1)
    fmt_out = fmt.mult_out()
    cs = canonical_codes(fmt)          # 21 codes
    co = canonical_codes(fmt_out)
    grids = np.meshgrid(cs, cs, cs, cs, co, indexing="ij")
    x0, y0, x1, y1, acc = (a.ravel() for a in grids)
    want = _mac_sequential(fmt, [x0, x1], [y0, y1], acc)
    got = _run_chain(fmt, 2, [x0, x1], [y0, y1], acc)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("fmt,k,extended,rounding", [
    (FPFormat(3, 2), 2, False, RNE),
    (FPFormat(3, 2), 3, False, RTZ),
    (FPFormat(3, 2), 2, True, RNE),
    (FPFormat(5, 2), 4, False, RNE),   # hobflops8
    (FPFormat(5, 3), 4, False, RNE),   # hobflops9
])
def test_mac_chain_matches_sequential(fmt, k, extended, rounding):
    fmt_out = fmt.mult_out(extended)
    rng = np.random.default_rng(fmt.w_e * 100 + fmt.w_f * 10 + k)
    n = 8192
    cc, co = canonical_codes(fmt), canonical_codes(fmt_out)
    xs = [cc[rng.integers(0, len(cc), n)] for _ in range(k)]
    ys = [cc[rng.integers(0, len(cc), n)] for _ in range(k)]
    acc = co[rng.integers(0, len(co), n)]
    want = _mac_sequential(fmt, xs, ys, acc, extended, rounding)
    got = _run_chain(fmt, k, xs, ys, acc, extended, rounding)
    np.testing.assert_array_equal(got, want)


def test_mac_chain_fewer_raw_gates():
    for fmt in (FPFormat(5, 2), FPFormat(5, 3), FPFormat(5, 10)):
        k = 4
        chain = build_mac_chain(fmt, k).live_gate_count()
        single = build_mac(fmt).live_gate_count()
        assert chain < k * single, (fmt, chain, k * single)


@pytest.mark.parametrize("lib", ["tpu_vpu", "avx2", "neon", "avx512"])
def test_mac_chain_fewer_mapped_gates(lib):
    """The acceptance metric: optimized mapped chain beats k x single
    MAC for the paper's formats under every cell library."""
    for fmt in (FPFormat(5, 2), FPFormat(5, 3)):   # hobflops8 / hobflops9
        k = 4
        chain = lib_gate_count(optimize_mapped(build_mac_chain(fmt, k), lib),
                               lib)
        single = lib_gate_count(optimize_mapped(build_mac(fmt), lib), lib)
        assert chain < k * single, (lib, fmt, chain, k * single)


# ---------------------------------------------------------------------------
# Netlist optimization passes
# ---------------------------------------------------------------------------
def _mul_vectors(fmt):
    xs = canonical_codes(fmt)
    return np.repeat(xs, len(xs)), np.tile(xs, len(xs))


@pytest.mark.parametrize("lib", ["tpu_vpu", "avx2", "neon", "avx512"])
def test_optimize_mapped_preserves_semantics(lib):
    """Full pipeline (map + const-prop + remap + absorb) is semantics-
    preserving, exhaustively, for every cell library."""
    fmt = FPFormat(3, 2)
    g = build_mul(fmt, fmt.mult_out(), RNE)
    X, Y = _mul_vectors(fmt)
    want = run_netlist(g, {"x": X, "y": Y},
                       {"x": fmt.nbits, "y": fmt.nbits})
    opt = optimize_mapped(g, lib)
    got = run_netlist(opt, {"x": X, "y": Y},
                      {"x": fmt.nbits, "y": fmt.nbits})
    np.testing.assert_array_equal(got, want)
    assert (lib_gate_count(opt, lib)
            <= lib_gate_count(tech_map(g, CELL_LIBS[lib]()), lib))


@pytest.mark.parametrize("passes", [
    (const_prop,), (sweep,), (absorb_andn,),
    (const_prop, absorb_andn, sweep),
])
@pytest.mark.parametrize("lib", ["avx2", "avx512"])
def test_individual_passes_preserve_semantics(passes, lib):
    fmt = FPFormat(3, 2)
    g = tech_map(build_mul(fmt, fmt.mult_out(), RNE), CELL_LIBS[lib]())
    X, Y = _mul_vectors(fmt)
    want = run_netlist(g, {"x": X, "y": Y},
                       {"x": fmt.nbits, "y": fmt.nbits})
    for p in passes:
        g = p(g)
    got = run_netlist(g, {"x": X, "y": Y},
                      {"x": fmt.nbits, "y": fmt.nbits})
    np.testing.assert_array_equal(got, want)


def test_const_prop_folds_constants():
    g = Graph()
    a = g.input_bus("a", 2)
    # dead logic + constant-feedable LUT3
    g.LUT3(0b10010110, a[0], a[1], 0)      # xor3 with c=0 -> a0 ^ a1
    out = g.LUT3(0b11101000, a[0], a[1], 1)  # majority with c=1 -> a0 | a1
    g.output_bus("out", [out])
    opt = const_prop(g)
    vals = eval_netlist(opt, {"a": np.array(
        [[0, 1, 0, 1], [0, 0, 1, 1]], dtype=np.uint64)})["out"][0]
    np.testing.assert_array_equal(vals, [0, 1, 1, 1])
    from repro.core.circuit import OP_LUT3
    assert all(n.op != OP_LUT3 for n in opt.nodes)


def test_absorb_andn_fuses_single_fanout_not():
    g = Graph()
    a = g.input_bus("a", 1)[0]
    b = g.input_bus("b", 1)[0]
    g.output_bus("out", [g.AND(a, g.NOT(b))])
    fused = absorb_andn(g)
    assert fused.live_gate_count() == 1
    vals = eval_netlist(fused, {
        "a": np.array([[0, 0, 1, 1]], dtype=np.uint64),
        "b": np.array([[0, 1, 0, 1]], dtype=np.uint64)})["out"][0]
    np.testing.assert_array_equal(vals, [0, 0, 1, 0])


def test_sweep_drops_dead_nodes():
    g = Graph()
    a = g.input_bus("a", 2)
    keep = g.XOR(a[0], a[1])
    g.AND(a[0], a[1])          # dead
    g.output_bus("out", [keep])
    assert len(sweep(g).nodes) < len(g.nodes)


def test_hash_consing_shares_structure():
    g = Graph()
    a = g.input_bus("a", 1)[0]
    b = g.input_bus("b", 1)[0]
    x1 = g.AND(a, b)
    x2 = g.AND(b, a)      # commuted -> same node
    assert x1 == x2
    assert g.XOR(a, a) == 0        # FALSE
    assert g.OR(a, g.NOT(a)) == 1  # TRUE
