"""Gate-level circuits vs the softfloat oracle — exhaustive for small
formats — plus tech-mapping equivalence (the Yosys-SAT analogue) and
gate-count regression guards."""
import numpy as np
import pytest

from repro.core import softfloat as sf
from repro.core.bitslice import pack_planes_np, unpack_planes_np
from repro.core.circuit import Graph
from repro.core.codegen import eval_netlist
from repro.core.fpcore import build_add, build_mac, build_mul
from repro.core.fpformat import RNE, RTZ, FPFormat
from repro.core.opt import CELL_LIBS, tech_map

from test_softfloat import canonical_codes


def run_netlist(g, inputs_codes: dict, widths: dict):
    planes = {name: pack_planes_np(codes, widths[name])
              for name, codes in inputs_codes.items()}
    out = eval_netlist(g, planes)["out"]
    n = len(next(iter(inputs_codes.values())))
    return unpack_planes_np(out, n)


@pytest.mark.parametrize("rounding", [RNE, RTZ])
@pytest.mark.parametrize("extended", [False, True])
def test_mul_exhaustive(rounding, extended):
    fmt = FPFormat(3, 2)
    fmt_out = fmt.mult_out(extended)
    xs = canonical_codes(fmt)
    X, Y = np.repeat(xs, len(xs)), np.tile(xs, len(xs))
    g = build_mul(fmt, fmt_out, rounding)
    got = run_netlist(g, {"x": X, "y": Y},
                      {"x": fmt.nbits, "y": fmt.nbits})
    want = sf.fp_mul(X, Y, fmt, fmt_out, rounding)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("rounding", [RNE, RTZ])
@pytest.mark.parametrize("fmt", [FPFormat(3, 3), FPFormat(4, 2)])
def test_add_exhaustive(rounding, fmt):
    xs = canonical_codes(fmt)
    X, Y = np.repeat(xs, len(xs)), np.tile(xs, len(xs))
    g = build_add(fmt, rounding)
    got = run_netlist(g, {"x": X, "y": Y},
                      {"x": fmt.nbits, "y": fmt.nbits})
    want = sf.fp_add(X, Y, fmt, rounding)
    np.testing.assert_array_equal(got, want)


def test_mac_random():
    fmt = FPFormat(5, 2)   # hobflops8
    fmt_out = fmt.mult_out()
    rng = np.random.default_rng(0)
    n = 4096
    X = canonical_codes(fmt)[rng.integers(0, 2 ** fmt.nbits - 300, n) % 261]
    Y = canonical_codes(fmt)[rng.integers(0, 261, n)]
    A = canonical_codes(fmt_out)[rng.integers(
        0, len(canonical_codes(fmt_out)), n)]
    g = build_mac(fmt)
    got = run_netlist(g, {"x": X, "y": Y, "acc": A},
                      {"x": fmt.nbits, "y": fmt.nbits,
                       "acc": fmt_out.nbits})
    want = sf.fp_mac(X, Y, A, fmt, fmt_out)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("lib", ["tpu_vpu", "avx2", "neon", "avx512"])
def test_tech_map_preserves_semantics(lib):
    fmt = FPFormat(3, 2)
    fmt_out = fmt.mult_out()
    xs = canonical_codes(fmt)
    X, Y = np.repeat(xs, len(xs)), np.tile(xs, len(xs))
    g = build_mul(fmt, fmt_out, RNE)
    mapped = tech_map(g, CELL_LIBS[lib]())
    got = run_netlist(mapped, {"x": X, "y": Y},
                      {"x": fmt.nbits, "y": fmt.nbits})
    want = run_netlist(g, {"x": X, "y": Y},
                       {"x": fmt.nbits, "y": fmt.nbits})
    np.testing.assert_array_equal(got, want)


def test_lib_ordering_matches_paper():
    """Paper: AVX512 (ternary LUT) < Neon (SEL) < AVX2 (2-input) in
    bitwise op count for the same MAC."""
    fmt = FPFormat(5, 2)
    g = build_mac(fmt)
    gates = {lib: tech_map(g, CELL_LIBS[lib]()).live_gate_count()
             for lib in ("avx2", "neon", "avx512")}
    assert gates["avx512"] < gates["neon"] < gates["avx2"]


def test_rtz_smaller_than_rne():
    """Paper §4: round-towards-zero removes the rounding adder."""
    fmt = FPFormat(5, 3)
    rne = build_mac(fmt, rounding=RNE).live_gate_count()
    rtz = build_mac(fmt, rounding=RTZ).live_gate_count()
    assert rtz < rne


def test_gate_count_monotone_in_precision():
    g8 = build_mac(FPFormat(5, 2)).live_gate_count()
    g12 = build_mac(FPFormat(5, 6)).live_gate_count()
    g16 = build_mac(FPFormat(5, 10)).live_gate_count()
    assert g8 < g12 < g16


def test_hash_consing_shares_structure():
    g = Graph()
    a = g.input_bus("a", 1)[0]
    b = g.input_bus("b", 1)[0]
    x1 = g.AND(a, b)
    x2 = g.AND(b, a)      # commuted -> same node
    assert x1 == x2
    assert g.XOR(a, a) == 0        # FALSE
    assert g.OR(a, g.NOT(a)) == 1  # TRUE
