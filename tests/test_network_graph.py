"""The graph-structured bitslice-resident runner (DESIGN.md §9).

Acceptance-level checks: a residual + maxpool + mixed-precision graph
runs entirely in the plane domain, bit-exact to the per-layer
f32-boundary oracle, with exactly one entry encode and one exit decode
in the jaxpr; the pooling/add plane ops agree with their word-parallel
softfloat oracles; the validator replaces ad-hoc asserts with named
errors.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import softfloat as sf
from repro.core.bitslice import BitsliceActivation, pack_planes
from repro.core.fpformat import FPFormat
from repro.kernels.conv2d_bitslice.network import (ConvLayerSpec,
                                                   GraphValidationError,
                                                   HobflopsNetwork,
                                                   NetworkGraph)
from repro.kernels.conv2d_bitslice.ops import (add_activations,
                                               avgpool2d_activations,
                                               decode_activations,
                                               encode_activations,
                                               hobflops_relu_planes,
                                               maxpool2d_activations,
                                               relu_activations)

F8 = FPFormat(5, 2)    # hobflops8
F9 = FPFormat(5, 3)    # hobflops9
F11 = FPFormat(5, 5)   # hobflops11


def _rand(rng, shape, scale=1.0):
    return (rng.standard_normal(shape) * scale).astype(np.float32)


def _residual_pool_graph(rng, fmt_lo=F8, fmt_hi=F11, cin=4, width=8,
                         backend="jnp", interpret=False):
    """The acceptance topology: conv -> maxpool -> (conv -> conv) +
    skip -> relu -> strided conv -> avgpool head, mixing two operand
    precisions."""
    g = NetworkGraph(fmt_lo, backend=backend, interpret=interpret)
    c1 = g.conv("c1", g.input_name, _rand(rng, (3, 3, cin, width), 0.4),
                relu=True)
    p1 = g.maxpool2d("p1", c1, window=2)
    c2 = g.conv("c2", p1, _rand(rng, (1, 1, width, width), 0.4),
                relu=True)
    c3 = g.conv("c3", c2, _rand(rng, (3, 3, width, width), 0.3),
                fmt_hi)                       # late layer: high precision
    res = g.add("res", c3, p1)                # skip auto-casts p1 up
    r = g.relu("r", res)
    d = g.conv("d", r, _rand(rng, (3, 3, width, width), 0.3), fmt_lo,
               stride=2)                      # strided downsample
    g.output(g.avgpool2d("head", d, window=2))
    return g


def test_residual_pool_graph_bit_exact():
    """Tentpole acceptance: the branched, pooled, mixed-precision graph
    is bit-exact between the resident and f32-boundary oracle paths."""
    rng = np.random.default_rng(0)
    g = _residual_pool_graph(rng)
    img = _rand(rng, (1, 8, 8, 4))
    res = np.asarray(g.run(img))
    ref = np.asarray(g.run_roundtrip(img))
    assert res.shape == g.out_shape(img.shape)
    np.testing.assert_array_equal(res, ref)


def test_strided_graph_single_encode_decode():
    """The one-encode/one-decode invariant holds for a branched graph
    with a stride-2 conv and pooling: exactly one f32->i32 bitcast
    (entry) and one i32->f32 (exit) in the whole jaxpr."""
    from conftest import count_primitives
    rng = np.random.default_rng(1)
    g = _residual_pool_graph(rng)
    img = _rand(rng, (1, 8, 8, 4))
    jaxpr = jax.make_jaxpr(
        lambda x: g._resident_fn(x, g._weights))(img)
    assert count_primitives(jaxpr.jaxpr, "bitcast_convert_type") == 2


def test_resident_stride2_valid_graph():
    """stride=2 + padding=VALID through the resident graph path."""
    rng = np.random.default_rng(2)
    g = NetworkGraph(F9)
    c1 = g.conv("c1", g.input_name, _rand(rng, (3, 3, 4, 8), 0.4),
                stride=2, padding="VALID", relu=True)
    p = g.maxpool2d("p", c1, window=2, padding="VALID")
    g.output(p)
    img = _rand(rng, (2, 9, 9, 4))
    res = np.asarray(g.run(img))
    assert res.shape == g.out_shape(img.shape) == (2, 2, 2, 8)
    np.testing.assert_array_equal(res, np.asarray(g.run_roundtrip(img)))


@pytest.mark.parametrize("padding", ["VALID", "SAME"])
def test_maxpool_matches_f32(padding):
    """Plane-domain maxpool == f32 maxpool on already-quantized values
    (max only selects, never rounds).  The odd 5x5 spatial size makes
    SAME actually pad, exercising the -inf fill planes."""
    rng = np.random.default_rng(3)
    img = _rand(rng, (1, 5, 5, 5), 2.0)
    act = encode_activations(jnp.asarray(img), F9)
    q = np.asarray(decode_activations(act))           # quantized input
    out = maxpool2d_activations(act, window=2, padding=padding)
    got = np.asarray(decode_activations(out))
    pads = "VALID" if padding == "VALID" else "SAME"
    want = np.asarray(jax.lax.reduce_window(
        jnp.asarray(q), -jnp.inf, jax.lax.max, (1, 2, 2, 1),
        (1, 2, 2, 1), pads))
    assert out.fmt == F9
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("padding", ["VALID", "SAME"])
def test_window_gather_stride_gt_window(padding):
    """stride (3) > window (2): gathered windows skip pixels entirely;
    the plane-domain maxpool still equals the f32 reduce_window on
    quantized input under both paddings."""
    rng = np.random.default_rng(20)
    img = _rand(rng, (1, 7, 7, 3), 2.0)
    act = encode_activations(jnp.asarray(img), F9)
    q = np.asarray(decode_activations(act))
    out = maxpool2d_activations(act, window=2, stride=3, padding=padding)
    got = np.asarray(decode_activations(out))
    want = np.asarray(jax.lax.reduce_window(
        jnp.asarray(q), -jnp.inf, jax.lax.max, (1, 2, 2, 1),
        (1, 3, 3, 1), padding))
    assert got.shape == want.shape
    np.testing.assert_array_equal(got, want)


def test_window_gather_window_equals_extent():
    """window == the whole input extent (global pooling): one output
    pixel; max equals the f32 global max, avg equals the pairwise
    fp_add tree + fp_scale oracle."""
    rng = np.random.default_rng(21)
    img = _rand(rng, (2, 4, 4, 3), 2.0)
    act = encode_activations(jnp.asarray(img), F9)
    q = np.asarray(decode_activations(act))
    gmax = maxpool2d_activations(act, window=4, padding="VALID")
    got = np.asarray(decode_activations(gmax))
    assert got.shape == (2, 1, 1, 3)
    np.testing.assert_array_equal(got[:, 0, 0], q.max(axis=(1, 2)))
    gavg = avgpool2d_activations(act, window=4, padding="VALID")
    codes = np.asarray(sf.encode_jnp(jnp.asarray(img), F9))
    from repro.kernels.conv2d_bitslice.ops import _fold_pairwise
    wins = [codes[:, i, j, :] for i in range(4) for j in range(4)]
    s = _fold_pairwise(wins, lambda a, b: sf.fp_add(a, b, F9))
    want = sf.decode(sf.fp_scale(s, 4, F9), F9).astype(np.float32)
    np.testing.assert_array_equal(
        np.asarray(decode_activations(gavg))[:, 0, 0], want)


def test_window_gather_pad_fill_codes():
    """Direct geometry check of window_gather_planes under SAME-style
    padding: pad slots of every plane decode to exactly the fill code
    (-inf for max, +0 for avg), real slots to the source pixels."""
    from repro.core.bitslice import unpack_planes, window_gather_planes
    from repro.kernels.conv2d_bitslice.ops import neg_inf_code
    rng = np.random.default_rng(22)
    B, H, W, C = 1, 3, 3, 2
    codes = rng.integers(0, 1 << F9.nbits, (B * H * W, C)).astype(np.int32)
    planes = pack_planes(jnp.asarray(codes), F9.nbits)
    for fill in (0, neg_inf_code(F9)):
        wins, (Ho, Wo) = window_gather_planes(
            planes, (B, H, W, C), 2, 2, stride=2, pad_h=1, pad_w=1,
            fill_code=fill)
        assert (Ho, Wo) == (2, 2)
        # pad split is low-half-first: pad_h=1 -> no top pad, one
        # bottom row; reference gather over the padded code grid
        grid = np.full((H + 1, W + 1, C), fill, np.int64)
        grid[:H, :W] = codes.reshape(H, W, C)
        for k, (i, j) in enumerate((i, j) for i in range(2)
                                   for j in range(2)):
            got = np.asarray(unpack_planes(wins[k]))[:, :C]
            want = grid[i::2, j::2][:2, :2].reshape(Ho * Wo, C)
            np.testing.assert_array_equal(got, want, err_msg=f"win {k}")


def test_avgpool_same_pad_counts_include_pad():
    """avgpool SAME on an odd extent: +0 fill slots participate in the
    add tree and the divisor stays the full window area — bit-exact to
    the word-parallel oracle fold."""
    from repro.kernels.conv2d_bitslice.network import GraphNode, _oracle_pool
    rng = np.random.default_rng(23)
    img = _rand(rng, (1, 5, 5, 3), 2.0)
    act = encode_activations(jnp.asarray(img), F9)
    q = np.asarray(decode_activations(act))
    out = avgpool2d_activations(act, window=2, padding="SAME")
    got = np.asarray(decode_activations(out))
    nd = GraphNode("p", "avgpool2d", ("x",), stride=2, padding="SAME",
                   window=(2, 2))
    want = np.asarray(_oracle_pool(jnp.asarray(q), F9, nd))
    assert got.shape == (1, 3, 3, 3)
    np.testing.assert_array_equal(got, want)


def test_avgpool_matches_oracle():
    """Plane-domain avgpool == fp_add tree + fp_scale on codes."""
    rng = np.random.default_rng(4)
    img = _rand(rng, (1, 4, 4, 3), 2.0)
    act = encode_activations(jnp.asarray(img), F9)
    out = avgpool2d_activations(act, window=2)
    got = np.asarray(decode_activations(out))
    codes = np.asarray(sf.encode_jnp(jnp.asarray(img), F9))
    w = codes.reshape(1, 2, 2, 2, 2, 3)
    # same pairwise fold order as the plane path: ((w00+w01)+(w10+w11))
    s = sf.fp_add(sf.fp_add(w[:, :, 0, :, 0], w[:, :, 0, :, 1], F9),
                  sf.fp_add(w[:, :, 1, :, 0], w[:, :, 1, :, 1], F9), F9)
    want = sf.decode(sf.fp_scale(s, 2, F9), F9).astype(np.float32)
    np.testing.assert_array_equal(got, want)


def test_add_activations_auto_cast():
    """Residual add across formats: the lower-precision branch is cast
    up, the sum equals the word-parallel oracle."""
    rng = np.random.default_rng(5)
    a_f = _rand(rng, (1, 2, 2, 7), 2.0)
    b_f = _rand(rng, (1, 2, 2, 7), 2.0)
    a = encode_activations(jnp.asarray(a_f), F11)
    b = encode_activations(jnp.asarray(b_f), F8)
    out = add_activations(a, b)                       # target: a.fmt
    assert out.fmt == F11 and out.shape == a.shape
    got = np.asarray(decode_activations(out))
    ca = sf.encode(a_f.astype(np.float64), F11)
    cb = sf.fp_cast(sf.encode(b_f.astype(np.float64), F8), F8, F11)
    want = sf.decode(sf.fp_add(ca, cb, F11), F11).astype(np.float32)
    np.testing.assert_array_equal(got.ravel(), want.ravel())


def test_relu_planes_exhaustive_vs_oracle():
    """Satellite: pin hobflops_relu_planes semantics (sign-set codes ->
    +0, canonical NaN propagates) against softfloat.fp_relu over every
    canonical code of a small format, plus every non-canonical sign-set
    exception code."""
    from test_softfloat import canonical_codes
    fmt = FPFormat(3, 2)
    xs = canonical_codes(fmt)
    # add the non-canonical negative NaN to pin its mapping too
    neg_nan = sf.pack(3, 1, 0, 0, fmt)
    xs = np.concatenate([xs, np.atleast_1d(neg_nan)])
    from repro.core.bitslice import pack_planes_np, unpack_planes_np
    planes = pack_planes_np(xs, fmt.nbits)
    got = unpack_planes_np(hobflops_relu_planes(planes, fmt), len(xs))
    want = sf.fp_relu(xs, fmt)
    np.testing.assert_array_equal(got, want)
    # spot-check the documented semantics
    assert sf.fp_relu(neg_nan, fmt) == 0                 # -NaN -> +0
    assert sf.fp_relu(sf.pack(2, 1, 0, 0, fmt), fmt) == 0   # -inf -> +0
    nan = sf.pack(3, 0, 0, 0, fmt)
    assert sf.fp_relu(nan, fmt) == nan                   # +NaN stays


def test_relu_activations_wrapper():
    rng = np.random.default_rng(6)
    act = encode_activations(jnp.asarray(_rand(rng, (1, 3, 3, 4))), F9)
    out = relu_activations(act)
    got = np.asarray(decode_activations(out))
    want = np.maximum(np.asarray(decode_activations(act)), 0.0)
    np.testing.assert_array_equal(got, want)


def test_graph_pallas_interpret_matches_jnp():
    """The graph runner with the Pallas conv backend (interpret mode on
    CPU) is bit-identical to the jnp backend."""
    img = _rand(np.random.default_rng(7), (1, 6, 6, 4))
    want = np.asarray(_residual_pool_graph(
        np.random.default_rng(8)).run(img))
    got = np.asarray(_residual_pool_graph(
        np.random.default_rng(8), backend="pallas",
        interpret=True).run(img))
    np.testing.assert_array_equal(got, want)


def test_validator_unknown_input():
    g = NetworkGraph(F8)
    with pytest.raises(GraphValidationError, match="unknown input"):
        g.relu("r", "nope")


def test_validator_duplicate_name():
    g = NetworkGraph(F8)
    g.relu("r", g.input_name)
    with pytest.raises(GraphValidationError, match="duplicate"):
        g.relu("r", g.input_name)


def test_validator_channel_mismatch():
    rng = np.random.default_rng(9)
    g = NetworkGraph(F8)
    c1 = g.conv("c1", g.input_name, _rand(rng, (1, 1, 4, 8)))
    g.conv("c2", c1, _rand(rng, (1, 1, 6, 8)))      # cin 6 != cout 8
    with pytest.raises(GraphValidationError, match="c2.*8 channels"):
        g.output("c2")


def test_validator_add_shape_mismatch():
    rng = np.random.default_rng(10)
    g = NetworkGraph(F8)
    c1 = g.conv("c1", g.input_name, _rand(rng, (3, 3, 4, 8)), stride=2)
    c2 = g.conv("c2", g.input_name, _rand(rng, (3, 3, 4, 8)))
    g.add("sum", c1, c2)
    g.output("sum")
    with pytest.raises(GraphValidationError, match="branch shapes"):
        g.run(_rand(rng, (1, 8, 8, 4)))


def test_validator_conv_window_fit():
    """An ill-sized conv raises a named error from shape_plan, not a
    bare ZeroDivisionError from the tiling code."""
    rng = np.random.default_rng(12)
    g = NetworkGraph(F8)
    g.conv("c1", g.input_name, _rand(rng, (3, 3, 4, 8)), padding="VALID")
    g.output("c1")
    with pytest.raises(GraphValidationError, match="does not fit"):
        g.run(_rand(rng, (1, 2, 2, 4)))


def test_dead_branch_pruned():
    """Nodes that do not feed the output are neither traced nor shipped
    into the jitted call."""
    rng = np.random.default_rng(13)
    g = NetworkGraph(F8)
    c1 = g.conv("c1", g.input_name, _rand(rng, (1, 1, 4, 8), 0.4))
    g.conv("dead", g.input_name, _rand(rng, (3, 3, 4, 8), 0.4))
    g.output(c1)
    assert set(g._live_weights) == {"c1"}
    img = _rand(rng, (1, 4, 4, 4))
    np.testing.assert_array_equal(np.asarray(g.run(img)),
                                  np.asarray(g.run_roundtrip(img)))


def test_validator_avgpool_window_pow2():
    g = NetworkGraph(F8)
    with pytest.raises(GraphValidationError, match="power of two"):
        g.avgpool2d("p", g.input_name, window=3)


def test_validator_frozen_after_output():
    g = NetworkGraph(F8)
    g.relu("r", g.input_name)
    g.output("r")
    with pytest.raises(GraphValidationError, match="frozen"):
        g.relu("r2", "r")


def test_hobflops_network_is_linear_graph():
    """The sequential wrapper lowers onto conv0..convN nodes of a
    NetworkGraph and stays bit-exact through it."""
    rng = np.random.default_rng(11)
    img = _rand(rng, (1, 6, 6, 4))
    specs = [ConvLayerSpec(_rand(rng, (3, 3, 4, 8), 0.4), F8),
             ConvLayerSpec(_rand(rng, (1, 1, 8, 8), 0.4), F9)]
    net = HobflopsNetwork(specs)
    assert isinstance(net.graph, NetworkGraph)
    assert [n.kind for n in net.graph._nodes.values()] == \
        ["input", "conv", "conv"]
    np.testing.assert_array_equal(np.asarray(net(img)),
                                  np.asarray(net.run_roundtrip(img)))
