"""Serving engine: wave scheduling, padding, eviction, quantized path."""
import jax
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import model_schema
from repro.models.schema import init_params
from repro.serve.engine import Request, ServeEngine

KEY = jax.random.PRNGKey(0)


def make_engine(arch="qwen2-0.5b", quant=None, n_slots=3):
    cfg = smoke_config(arch)
    params = init_params(model_schema(cfg), KEY)
    deq = None
    if quant:
        from repro.quant.apply import quantize_params
        params, deq = quantize_params(params, cfg, quant)
    return cfg, ServeEngine(cfg, params, n_slots=n_slots, max_len=64,
                            deq=deq)


def test_engine_serves_mixed_lengths():
    cfg, eng = make_engine()
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab, size=n).astype(np.int32),
                    max_new=5) for i, n in enumerate([7, 12, 3, 9, 4])]
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert len(done) == 5
    assert all(r.done and len(r.out) == 5 for r in done)
    assert eng.total_decode_steps > 0


def test_engine_eos_stops_early():
    cfg, eng = make_engine()
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab, size=8).astype(np.int32)
    # find what the model emits first, then use it as EOS for a second
    # identical request — it must stop after 1 token.
    r1 = Request(0, prompt, max_new=6)
    eng.submit(r1)
    eng.run()
    eos = r1.out[0]
    r2 = Request(1, prompt, max_new=6, eos_id=int(eos))
    eng.submit(r2)
    eng.run()
    assert len(r2.out) == 1 and r2.out[0] == eos


def test_engine_matches_single_request_decode():
    """Batch-of-1 wave equals the plain serve loop token-for-token."""
    from repro.serve.steps import make_decode_step, make_prefill_step
    import jax.numpy as jnp
    cfg, eng = make_engine(n_slots=1)
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab, size=10).astype(np.int32)
    req = Request(0, prompt, max_new=6)
    eng.submit(req)
    eng.run()

    pf = jax.jit(make_prefill_step(cfg, 64))
    st = jax.jit(make_decode_step(cfg))
    params = eng.params
    cache, lg, length = pf(params, {"tokens": jnp.asarray(prompt[None])})
    toks = [int(jnp.argmax(lg, -1)[0])]
    tok = jnp.argmax(lg, -1).astype(jnp.int32)
    pos = jnp.asarray(length, jnp.int32)
    for i in range(5):
        tok, lg2, cache = st(params, tok, pos + i, cache)
        toks.append(int(tok[0]))
    assert req.out == toks


def test_engine_quantized_weights():
    cfg, eng = make_engine(quant="hobflops9")
    rng = np.random.default_rng(3)
    reqs = [Request(i, rng.integers(0, cfg.vocab, size=6).astype(np.int32),
                    max_new=3) for i in range(2)]
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert all(len(r.out) == 3 for r in done)
