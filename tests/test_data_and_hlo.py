"""Data pipeline determinism/learnability + the loop-aware HLO cost
analyzer (trip-count multiplication, comment stripping, collectives)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import SyntheticLM, make_batch
from repro.launch import hlo_cost


def test_data_deterministic():
    ds = SyntheticLM(vocab=101, seq_len=16, global_batch=4, seed=3)
    b1, b2 = make_batch(ds, 7), make_batch(ds, 7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = make_batch(ds, 8)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_data_labels_shifted():
    ds = SyntheticLM(vocab=50, seq_len=8, global_batch=2)
    b = make_batch(ds, 0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])
    assert b["tokens"].max() < 50 and b["tokens"].min() >= 0


def test_data_learnable_structure():
    """90% of transitions follow the LCG rule — a model can learn it."""
    ds = SyntheticLM(vocab=97, seq_len=256, global_batch=4, seed=0)
    b = make_batch(ds, 0)
    toks, labs = b["tokens"], b["labels"]
    rows = np.zeros(4, dtype=np.int64)
    # infer per-row offset from the first transition that matches
    matches = 0
    total = 0
    for r in range(4):
        # recover offset: labels = (t*A + C + row) % V for ~90% of pos
        cand = (labs[r].astype(np.int64)
                - (toks[r].astype(np.int64) * 1103515245 + 12345)) % 97
        vals, counts = np.unique(cand, return_counts=True)
        row = vals[counts.argmax()]
        pred = (toks[r].astype(np.int64) * 1103515245 + 12345 + row) % 97
        matches += (pred == labs[r]).sum()
        total += labs.shape[1]
    assert matches / total > 0.8


# ---------------------------------------------------------------------------
# HLO cost analyzer
# ---------------------------------------------------------------------------
def test_trip_count_multiplication():
    def fn(x, ws):
        def step(c, w):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(step, x, ws)
        return y

    costs = {}
    for depth in (4, 8):
        c = jax.jit(fn).lower(
            jax.ShapeDtypeStruct((64, 128), jnp.float32),
            jax.ShapeDtypeStruct((depth, 128, 128), jnp.float32)
        ).compile()
        costs[depth] = hlo_cost.analyze(c.as_text())
    per_layer = 2 * 64 * 128 * 128
    assert abs(costs[4].flops - 4 * per_layer) / (4 * per_layer) < 0.1
    assert abs(costs[8].flops - 8 * per_layer) / (8 * per_layer) < 0.1
    # bytes scale with depth too
    assert costs[8].bytes > 1.7 * costs[4].bytes


def test_comment_stripping_in_tuple_shapes():
    hlo = """
HloModule m

ENTRY %main (a: f32[4]) -> f32[4] {
  %a = f32[4]{0} parameter(0)
  %t = (f32[4]{0}, f32[4]{0}, f32[4]{0}, f32[4]{0}, f32[4]{0}, /*index=5*/f32[4]{0}) tuple(%a, %a, %a, %a, %a, %a)
  ROOT %r = f32[4]{0} get-tuple-element(%t), index=0
}
"""
    cost = hlo_cost.analyze(hlo)
    assert cost.flops == 0  # tuple/GTE are free; parse must not crash


def test_collective_bytes():
    hlo = """
HloModule m

ENTRY %main (a: f32[1024]) -> f32[1024] {
  %a = f32[1024]{0} parameter(0)
  %ar = f32[1024]{0} all-reduce(%a), replica_groups={}, to_apply=%add
  ROOT %ag = f32[1024]{0} all-gather(%ar), dimensions={0}
}
"""
    cost = hlo_cost.analyze(hlo)
    assert cost.coll_bytes == 2 * 1024 * 4
    assert cost.coll_hist["all-reduce"] == 4096
    assert cost.coll_hist["all-gather"] == 4096


def test_dot_flops_with_batch_dims():
    def fn(a, b):
        return jnp.einsum("bij,bjk->bik", a, b)
    c = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((4, 32, 64), jnp.float32),
        jax.ShapeDtypeStruct((4, 64, 16), jnp.float32)).compile()
    cost = hlo_cost.analyze(c.as_text())
    want = 2 * 4 * 32 * 64 * 16
    assert abs(cost.flops - want) / want < 0.05
