"""Sharding rules: divisibility fallback, candidate lists, cache specs,
and the dry-run input-spec plumbing (no 512-device requirement)."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import (ARCH_NAMES, batch_specs, cache_specs,
                           get_config, smoke_config)
from repro.distributed.sharding import batch_pspecs, cache_pspecs
from repro.models import model_schema
from repro.models.config import SHAPES
from repro.models.schema import Rules, logical_spec, make_rules, pspecs


def fake_rules(pod=2, data=16, model=16, seq_parallel=True):
    table_mesh = {"pod": pod, "data": data, "model": model}
    axes = [a for a, s in table_mesh.items() if s]

    class M:
        axis_names = tuple(axes)
        class devices:
            shape = tuple(table_mesh[a] for a in axes)
    return make_rules(M, seq_parallel=seq_parallel)


def test_divisibility_fallback():
    rules = fake_rules()
    # 14 heads cannot shard over model=16 -> replicate
    assert logical_spec(rules, "batch", None, "qheads", None,
                        dims=(128, 4096, 14, 64)) == \
        P(("pod", "data"), None, None, None)
    # 128 heads can
    assert logical_spec(rules, "batch", None, "qheads", None,
                        dims=(128, 4096, 128, 64))[2] == "model"


def test_kvseq_candidates():
    rules = fake_rules()
    # batch=1 (long-context): kvseq takes the widest split
    spec = logical_spec(rules, "layers", "batch", "kvseq", "kvheads", None,
                        dims=(32, 1, 524288, 8, 128))
    assert spec[2] == ("pod", "data", "model")
    # batch shardable: data axes consumed, kvseq falls back to model
    spec = logical_spec(rules, "layers", "batch", "kvseq", "kvheads", None,
                        dims=(32, 128, 32768, 8, 128))
    assert spec[1] == ("pod", "data") and spec[2] == "model"


def test_param_pspecs_use_both_axes():
    cfg = get_config("llama3-405b")
    rules = fake_rules()
    specs = pspecs(model_schema(cfg), rules)
    wq = specs["blocks"]["b0"]["attn"]["wq"]
    # [layers, d_model, q_heads*dh]: FSDP over data axes + TP over model
    assert wq == P(None, ("pod", "data"), "model")


def test_moe_expert_sharding_by_count():
    rules = fake_rules()
    olmoe = pspecs(model_schema(get_config("olmoe-1b-7b")), rules)
    grok = pspecs(model_schema(get_config("grok-1-314b")), rules)
    # olmoe: 64 experts % 16 == 0 -> EP over model
    assert olmoe["blocks"]["b0"]["moe"]["w_gate"][1] == "model"
    # grok: 8 experts -> replicate experts, TP falls to d_ff (emlp)
    g = grok["blocks"]["b0"]["moe"]["w_gate"]
    assert g[1] is None and g[3] == "model"


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_cache_specs_build(arch):
    cfg = get_config(arch)
    rules = fake_rules()
    for shape_name in ("decode_32k", "long_500k"):
        from repro.models.config import shape_applicable
        shape = SHAPES[shape_name]
        ok, _ = shape_applicable(cfg, shape)
        if not ok:
            continue
        cache = cache_specs(cfg, shape)
        specs = cache_pspecs(cache, rules)
        assert jax.tree.structure(specs, is_leaf=lambda x: isinstance(
            x, P)) == jax.tree.structure(
                cache, is_leaf=lambda x: hasattr(x, "shape"))


def test_batch_pspecs():
    cfg = get_config("internvl2-26b")
    rules = fake_rules()
    specs = batch_pspecs(batch_specs(cfg, SHAPES["train_4k"], train=True),
                         rules)
    assert specs["tokens"] == P(("pod", "data"), None)
    assert specs["prefix"] == P(("pod", "data"), None, None)


def test_smoke_configs_preserve_topology():
    for arch in ARCH_NAMES:
        full, small = get_config(arch), smoke_config(arch)
        assert full.family == small.family
        assert (full.moe_experts > 0) == (small.moe_experts > 0)
        assert (full.ssm_state > 0) == (small.ssm_state > 0)
        assert full.scan_period() >= small.scan_period() or True
        assert small.n_layers % small.scan_period() == 0
