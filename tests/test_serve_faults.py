"""Serving robustness: fault injection, SLO admission, overload control
(DESIGN.md §11).

Acceptance-level checks: every injected failure mode — runner compile
failure, transient wave-execution error, artificial straggler,
corrupted runner-cache entry, corrupted tune-cache file — recovers
with *zero wrong answers*: every completed response stays bit-identical
to ``graph.run`` on that request alone at the precision it was served
at, and degraded responses are explicitly tagged.  Plus the admission
SLO: a lone request is served within ``wave_deadline_ms`` instead of
waiting for a full bucket, bad payloads are rejected at ``submit()``
with typed errors before they can poison a wave, a bounded queue sheds
with ``QueueFullError``, and a failed wave quarantines only its own
requests.

All chaos is deterministic (counter budgets + the fixed
``HOBFLOPS_CHAOS_SEED``); the CI chaos job replays this file with the
seed pinned.
"""
import json

import numpy as np
import pytest

from repro.core.fpformat import FPFormat
from repro.ft.heartbeat import stale_hosts
from repro.ft.straggler import StragglerMonitor
from repro.kernels.conv2d_bitslice.network import NetworkGraph
from repro.serve_conv import (ConvRequest, ConvServeEngine,
                              DeadlineExceededError, FaultInjector,
                              FaultPlan, QueueFullError,
                              RequestValidationError, ServePolicy,
                              WaveExecutionError, corrupt_runner_cache,
                              corrupt_tune_cache, load_tune_cache,
                              tuned_conv_blocks)
from repro.serve_conv.cache import tune_key

F8 = FPFormat(5, 2)
F9 = FPFormat(5, 3)
HWC = (6, 6, 4)


def _rand(rng, shape, scale=1.0):
    return (rng.standard_normal(shape) * scale).astype(np.float32)


@pytest.fixture(scope="module")
def graphs():
    """One primary graph (F9) + its with_precision(F8) degraded
    variant, shared across the module so jit compiles amortize."""
    rng = np.random.default_rng(0)
    g = NetworkGraph(F9)
    c1 = g.conv("c1", g.input_name, _rand(rng, (3, 3, 4, 4), 0.4),
                relu=True)
    g.output(g.maxpool2d("head", c1, window=2))
    return g, g.with_precision(F8)


class FakeClock:
    """Deterministic engine clock for deadline/latency tests."""

    def __init__(self):
        self.now = 0.0

    def __call__(self) -> float:
        return self.now

    def advance(self, s: float):
        self.now += s


def _assert_bit_exact(req, graph):
    """A served request's output equals graph.run on it alone."""
    batched = req.image[None] if req.image.ndim == 3 else req.image
    solo = np.asarray(graph.run(batched))
    solo = solo[0] if req.image.ndim == 3 else solo
    np.testing.assert_array_equal(np.asarray(req.out), solo,
                                  err_msg=f"request {req.rid}")


# ---------------------------------------------------------------------------
# Admission: validation, bounded queue, deadlines
# ---------------------------------------------------------------------------
def test_submit_rejects_bad_payloads_without_poisoning(graphs):
    """Wrong rank/geometry, int dtype, and NaN/Inf payloads raise
    typed RequestValidationError at submit() — the queue stays clean
    and a subsequent good request is served bit-exactly."""
    g, _ = graphs
    rng = np.random.default_rng(1)
    eng = ConvServeEngine(g, HWC, max_batch=4)
    bad = [
        (_rand(rng, (6, 6)), "rank"),                     # rank 2
        (_rand(rng, (5, 5, 4)), "geometry"),              # wrong HxW
        (rng.integers(0, 9, (6, 6, 4)), "float"),         # int dtype
        (np.full((6, 6, 4), np.nan, np.float32), "non-finite"),
        (np.r_[np.inf, np.zeros(6 * 6 * 4 - 1)]
         .reshape(6, 6, 4).astype(np.float32), "non-finite"),
        (_rand(rng, (9, 6, 6, 4)), "max_batch"),          # oversized
    ]
    for i, (img, match) in enumerate(bad):
        with pytest.raises(RequestValidationError, match=match):
            eng.submit(ConvRequest(i, img))
    assert eng.pending_images() == 0
    assert eng.stats()["requests_rejected"] == len(bad)
    ok = ConvRequest(99, _rand(rng, HWC))
    eng.submit(ok)
    done = eng.run()
    assert [r.rid for r in done] == [99]
    _assert_bit_exact(ok, g)


def test_bounded_queue_sheds_with_typed_error(graphs):
    g, _ = graphs
    rng = np.random.default_rng(2)
    eng = ConvServeEngine(g, HWC, max_batch=4,
                          policy=ServePolicy(max_queue_images=2))
    eng.submit(ConvRequest(0, _rand(rng, HWC)))
    eng.submit(ConvRequest(1, _rand(rng, HWC)))
    with pytest.raises(QueueFullError, match="max_queue_images"):
        eng.submit(ConvRequest(2, _rand(rng, HWC)))
    assert eng.pending_images() == 2
    assert eng.stats()["requests_shed"] == 1
    done = eng.run()                  # the queue itself still serves
    assert len(done) == 2
    for r in done:
        _assert_bit_exact(r, g)


def test_wave_deadline_serves_lone_request(graphs):
    """Satellite acceptance: with wave_deadline_ms, a lone queued
    request is served once the deadline lapses instead of waiting
    (forever) for a full max_batch bucket."""
    g, _ = graphs
    rng = np.random.default_rng(3)
    clock = FakeClock()
    eng = ConvServeEngine(g, HWC, max_batch=8, clock=clock,
                          policy=ServePolicy(wave_deadline_ms=50.0))
    req = ConvRequest(0, _rand(rng, HWC))
    eng.submit(req)
    assert eng.step() == []                 # t=0: not full, not aged
    clock.advance(0.020)
    assert eng.step() == []                 # t=20ms: still young
    assert not eng.wave_ready()
    assert eng.next_deadline() == pytest.approx(0.050)
    clock.advance(0.035)                    # t=55ms: deadline lapsed
    done = eng.step()
    assert [r.rid for r in done] == [0] and eng.waves == 1
    _assert_bit_exact(req, g)
    # queue wait component of the tracked latency is the 55ms it aged
    assert req.e2e_latency_s >= 0.055


def test_wave_deadline_full_bucket_closes_immediately(graphs):
    """The other edge of deadline-or-full: a full wave never waits for
    the deadline."""
    g, _ = graphs
    rng = np.random.default_rng(4)
    clock = FakeClock()
    eng = ConvServeEngine(g, HWC, max_batch=2, clock=clock,
                          policy=ServePolicy(wave_deadline_ms=1e6))
    for i in range(2):
        eng.submit(ConvRequest(i, _rand(rng, HWC)))
    done = eng.step()                       # t=0, deadline far away
    assert len(done) == 2
    for r in done:
        _assert_bit_exact(r, g)


def test_per_request_deadline_expires_stale_requests(graphs):
    g, _ = graphs
    rng = np.random.default_rng(5)
    clock = FakeClock()
    eng = ConvServeEngine(g, HWC, max_batch=4, clock=clock,
                          policy=ServePolicy(request_timeout_ms=100.0))
    stale = ConvRequest(0, _rand(rng, HWC))
    eng.submit(stale)
    clock.advance(0.2)                      # ages past its deadline
    fresh = ConvRequest(1, _rand(rng, HWC))
    eng.submit(fresh)
    done = eng.run()
    assert [r.rid for r in done] == [1]
    _assert_bit_exact(fresh, g)
    assert stale.status == "expired" and stale.out is None
    assert isinstance(stale.error, DeadlineExceededError)
    assert eng.stats()["requests_expired"] == 1
    # per-request override beats the policy default
    slow_ok = ConvRequest(2, _rand(rng, HWC), deadline_ms=1e6)
    eng.submit(slow_ok)
    clock.advance(0.2)
    assert [r.rid for r in eng.run()] == [2]


# ---------------------------------------------------------------------------
# Fault injection: every mode recovers with zero wrong answers
# ---------------------------------------------------------------------------
def test_injected_compile_failure_recovers(graphs):
    g, _ = graphs
    rng = np.random.default_rng(6)
    faults = FaultInjector(FaultPlan(compile_failures=1))
    eng = ConvServeEngine(g, HWC, max_batch=4, faults=faults,
                          policy=ServePolicy(retry_backoff_s=1e-4))
    reqs = [ConvRequest(i, _rand(rng, HWC)) for i in range(3)]
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert len(done) == 3 and faults.injected_compile_failures == 1
    assert eng.executor.retries >= 1
    for r in done:
        _assert_bit_exact(r, g)
        assert r.status == "served"
    assert done[0].attempts == 2            # failed build + clean retry


def test_transient_wave_error_recovers(graphs):
    g, _ = graphs
    rng = np.random.default_rng(7)
    faults = FaultInjector(FaultPlan(wave_errors=1))
    eng = ConvServeEngine(g, HWC, max_batch=4, faults=faults,
                          policy=ServePolicy(retry_backoff_s=1e-4))
    reqs = [ConvRequest(i, _rand(rng, (2,) + HWC)) for i in range(2)]
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert len(done) == 2 and faults.injected_wave_errors == 1
    assert eng.stats()["wave_exec_failures"] == 1
    assert eng.stats()["waves_failed"] == 0      # retry healed it
    for r in done:
        _assert_bit_exact(r, g)


def test_exhausted_retries_quarantine_only_their_wave(graphs):
    """A wave that fails its whole retry budget marks its own requests
    failed (typed WaveExecutionError) — and the engine keeps serving:
    the next wave completes bit-exactly."""
    g, _ = graphs
    rng = np.random.default_rng(8)
    policy = ServePolicy(max_wave_retries=1, retry_backoff_s=1e-4)
    faults = FaultInjector(FaultPlan(wave_errors=2))   # = retry budget
    eng = ConvServeEngine(g, HWC, max_batch=4, faults=faults,
                          policy=policy)
    doomed = [ConvRequest(i, _rand(rng, HWC)) for i in range(2)]
    for r in doomed:
        eng.submit(r)
    assert eng.run_wave() == []
    for r in doomed:
        assert r.status == "failed" and r.out is None
        assert isinstance(r.error, WaveExecutionError)
        assert r.error.attempts == 2
    st = eng.stats()
    assert st["waves_failed"] == 1 and st["requests_failed"] == 2
    ok = ConvRequest(9, _rand(rng, HWC))
    eng.submit(ok)                    # budget exhausted: engine heals
    assert [r.rid for r in eng.run()] == [9]
    _assert_bit_exact(ok, g)


def test_corrupted_runner_cache_entry_evicted_and_rebuilt(graphs):
    """A poisoned cached runner (always raises) can only be cured by
    eviction + rebuild — the engine does exactly that and the answers
    stay bit-exact."""
    g, _ = graphs
    rng = np.random.default_rng(9)
    eng = ConvServeEngine(g, HWC, max_batch=4,
                          policy=ServePolicy(retry_backoff_s=1e-4))
    warm = ConvRequest(0, _rand(rng, HWC))
    eng.submit(warm)
    eng.run()                               # bucket-1 runner now cached
    corrupted = corrupt_runner_cache(eng.cache)
    assert len(corrupted) == 1
    req = ConvRequest(1, _rand(rng, HWC))
    eng.submit(req)
    done = eng.run()
    assert [r.rid for r in done] == [1]
    _assert_bit_exact(req, g)
    st = eng.stats()
    assert st["runner_cache"]["evictions"] >= 1
    assert st["waves_failed"] == 0


def test_straggler_waves_flagged_in_stats(graphs):
    """Artificially slow waves of one bucket class are flagged by the
    wired StragglerMonitor — and still answer bit-exactly."""
    g, _ = graphs
    rng = np.random.default_rng(10)
    faults = FaultInjector(FaultPlan())
    eng = ConvServeEngine(g, HWC, max_batch=4, faults=faults)

    def serve(n):
        reqs = [ConvRequest(i, _rand(rng, HWC)) for i in range(n)]
        for r in reqs:
            eng.submit(r)
        for r in eng.run():
            _assert_bit_exact(r, g)

    serve(1)                        # warm both buckets: compile time
    serve(4)                        # must not pollute the slow-EMA
    fresh = StragglerMonitor()
    eng.straggler = eng.executor.straggler = fresh
    faults.plan.straggle_waves, faults.plan.straggle_s = 3, 0.05
    for _ in range(3):
        serve(1)                    # straggled bucket-1 waves
    assert faults.injected_straggles == 3
    for _ in range(3):
        serve(4)                    # fast bucket-4 waves
    st = eng.stats()
    assert st["stragglers"] == ["bucket1"]
    assert fresh.ema("bucket1") > fresh.ema("bucket4")
    assert st["straggler_fleet"]["hosts"] == 2


def test_corrupted_tune_cache_warns_ignores_rebuilds(tmp_path):
    """Satellite: a truncated tune-cache JSON degrades to 'no cache'
    with a warning; the next save rewrites a valid file atomically."""
    rng = np.random.default_rng(11)
    img = _rand(rng, (1, 6, 6, 4))
    kern = _rand(rng, (1, 1, 4, 8), 0.3)
    path = str(tmp_path / "tune.json")
    cands = [{"c_unroll": 1, "m_block": 8}]
    blocks, _ = tuned_conv_blocks(img, kern, fmt=F8, path=path, iters=1,
                                  candidates=cands)
    corrupt_tune_cache(path)
    with pytest.warns(RuntimeWarning, match="corrupt"):
        assert load_tune_cache(path) == {}
    # miss again (cache unusable), sweep re-runs, file rebuilt
    with pytest.warns(RuntimeWarning, match="corrupt"):
        blocks2, dt2 = tuned_conv_blocks(img, kern, fmt=F8, path=path,
                                         iters=1, candidates=cands)
    assert blocks2 == blocks and dt2 is not None
    rebuilt = load_tune_cache(path)          # clean: no warning
    assert tune_key(img.shape, kern, F8, candidates=cands) in rebuilt
    with open(path) as f:
        json.load(f)                         # valid JSON on disk
    # non-dict top level is corrupt too
    (tmp_path / "t2.json").write_text("[1, 2, 3]")
    with pytest.warns(RuntimeWarning, match="corrupt"):
        assert load_tune_cache(str(tmp_path / "t2.json")) == {}


# ---------------------------------------------------------------------------
# Precision-degrading overload control
# ---------------------------------------------------------------------------
def test_overload_degrades_tags_and_recovers(graphs):
    """Sustained queue pressure routes waves to the registered
    cheaper-precision variant; every degraded response is tagged and
    bit-identical to the degraded graph's own run; pressure relief
    steps back up to full precision."""
    g, g8 = graphs
    rng = np.random.default_rng(12)
    policy = ServePolicy(degrade_queue_factor=1.0, degrade_patience=2,
                         recover_patience=2)
    eng = ConvServeEngine(g, HWC, max_batch=2, policy=policy)
    assert eng.register_degraded(g8, "hobflops8") == 1
    reqs = [ConvRequest(i, _rand(rng, HWC)) for i in range(10)]
    for r in reqs:
        eng.submit(r)                   # pressure: 5 waves of backlog
    done = eng.run()
    assert len(done) == 10
    by_level = {}
    for r in done:
        by_level.setdefault(r.precision, []).append(r)
        assert (r.level > 0) == r.degraded
        # bit-exact AT THE PRECISION IT WAS SERVED AT
        _assert_bit_exact(r, g if r.level == 0 else g8)
    assert set(by_level) == {"full", "hobflops8"}
    # wave 1 observes hot streak 1, wave 2 hits degrade_patience=2 and
    # is already served degraded: 2 full images, then 8 at hobflops8
    assert [r.precision for r in done[:2]] == ["full"] * 2
    assert all(r.precision == "hobflops8" for r in done[2:])
    st = eng.stats()["degradation"]
    assert st["activations"] == 1 and st["level"] == 1
    assert st["images_by_level"] == {"full": 2, "hobflops8": 8}
    # degraded codes really differ from full-precision codes
    assert not np.array_equal(np.asarray(done[-1].out),
                              np.asarray(g.run(done[-1].image[None]))[0])
    # light traffic: two cold observations recover full precision
    for i in range(2):
        eng.submit(ConvRequest(100 + i, _rand(rng, HWC)))
        for r in eng.run():
            _assert_bit_exact(r, g8 if r.degraded else g)
    assert eng.controller.level == 0
    late = ConvRequest(200, _rand(rng, HWC))
    eng.submit(late)
    assert eng.run()[0].precision == "full"
    _assert_bit_exact(late, g)


def test_degraded_variant_must_match_geometry(graphs):
    g, _ = graphs
    rng = np.random.default_rng(13)
    other = NetworkGraph(F8)
    c1 = other.conv("c1", other.input_name,
                    _rand(rng, (3, 3, 4, 7), 0.4))   # 7 != 4 channels
    other.output(c1)
    eng = ConvServeEngine(g, HWC, max_batch=2)
    with pytest.raises(ValueError, match="geometry"):
        eng.register_degraded(other)


def test_with_precision_preserves_structure(graphs):
    g, g8 = graphs
    assert g8._nodes.keys() == g._nodes.keys()
    assert g8.input_fmt == F8
    assert g8._nodes["c1"].precision == F8
    assert g8.out_shape((1,) + HWC) == g.out_shape((1,) + HWC)
    assert g8.signature() != g.signature()
    # idempotent at the same format: same compiled structure
    assert g.with_precision(F9).signature() == g.signature()


# ---------------------------------------------------------------------------
# Heartbeat liveness
# ---------------------------------------------------------------------------
def test_heartbeat_feeds_engine_liveness(graphs, tmp_path):
    g, _ = graphs
    rng = np.random.default_rng(14)
    eng = ConvServeEngine(g, HWC, max_batch=2,
                          heartbeat_dir=str(tmp_path), heartbeat_host="s0")
    for i in range(3):
        eng.submit(ConvRequest(i, _rand(rng, HWC)))
    eng.run()
    st = eng.stats()
    assert st["heartbeat"]["host"] == "s0"
    assert st["heartbeat"]["step"] == eng.waves
    assert eng.heartbeat.age_s() < 60
    assert stale_hosts(str(tmp_path), timeout_s=60) == []
