"""Gradient compression: unbiasedness, error feedback convergence, and
the shard_map psum path."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.distributed.compression import (compress_grads,
                                           compressed_psum,
                                           dequantize_int8,
                                           make_error_feedback,
                                           quantize_int8, wire_bytes)


def test_quantize_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(1024), jnp.float32)
    codes, scale = quantize_int8(x)
    back = dequantize_int8(codes, scale)
    assert float(jnp.max(jnp.abs(back - x))) <= float(scale) * 0.5 + 1e-7


def test_stochastic_rounding_unbiased():
    x = jnp.full((20000,), 0.3)
    codes, scale = quantize_int8(x, key=jax.random.PRNGKey(0))
    mean = float(jnp.mean(dequantize_int8(codes, scale)))
    assert abs(mean - 0.3) < 2e-3


def test_error_feedback_accumulates_to_truth():
    """Sum over steps of EF-compressed grads converges to sum of true
    grads (the EF telescoping property)."""
    rng = np.random.default_rng(1)
    true_sum = np.zeros(64, np.float32)
    sent_sum = np.zeros(64, np.float32)
    ef = make_error_feedback({"g": jnp.zeros(64)})
    for step in range(50):
        g = {"g": jnp.asarray(rng.standard_normal(64) * 0.01,
                              jnp.float32)}
        true_sum += np.asarray(g["g"])
        sent, ef = compress_grads(g, ef)
        sent_sum += np.asarray(sent["g"])
    # residual is bounded by one quantization step, not growing in t
    resid = np.abs(true_sum - sent_sum).max()
    assert resid < 0.01, resid


def test_compressed_psum_shard_map():
    from repro.launch.mesh import _mk
    mesh = _mk((1,), ("data",))
    x = jnp.arange(8, dtype=jnp.float32) / 7.0

    def f(x):
        return compressed_psum(x, "data")

    shard_map = getattr(jax, "shard_map", None)
    if shard_map is None:  # older jax keeps it in experimental
        from jax.experimental.shard_map import shard_map
    y = shard_map(f, mesh=mesh, in_specs=P(), out_specs=P())(x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=0.01)


def test_wire_bytes():
    g = {"a": jnp.zeros((128, 128)), "b": jnp.zeros(64)}
    assert wire_bytes(g, compressed=True) * 3.9 < wire_bytes(
        g, compressed=False)
