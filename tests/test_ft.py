"""Fault-tolerance: heartbeats, stragglers, elastic re-mesh planning."""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.ft import (Heartbeat, StragglerMonitor, Supervisor,
                      plan_remesh, stale_hosts)


def test_heartbeat_staleness(tmp_path):
    hb1 = Heartbeat(str(tmp_path), "hostA")
    hb2 = Heartbeat(str(tmp_path), "hostB")
    hb1.beat(5, 0.5, now=1000.0)
    hb2.beat(5, 0.5, now=1070.0)
    assert stale_hosts(tmp_path, timeout_s=60, now=1071.0) == ["hostA"]
    assert stale_hosts(tmp_path, timeout_s=600, now=1071.0) == []


def test_straggler_detection():
    mon = StragglerMonitor(factor=1.5)
    for _ in range(5):
        for h in ("a", "b", "c", "d"):
            mon.observe(h, 1.0)
        mon.observe("slow", 3.0)
    assert mon.stragglers() == ["slow"]
    assert mon.fleet_summary()["hosts"] == 5


@given(st.integers(0, 4096), st.sampled_from([8, 16, 32]))
@settings(max_examples=200, deadline=None)
def test_plan_remesh_properties(alive, mp):
    plan = plan_remesh(alive, mp, chips_per_pod=256)
    if alive < mp:
        assert plan is None
    if plan is not None:
        pods, data, model = plan
        assert model == mp
        assert pods >= 1 and data >= 1
        assert pods * data * model <= max(alive, 1)
        assert data & (data - 1) == 0   # power of two


def test_plan_remesh_full_fleet():
    assert plan_remesh(512, 16) == (2, 16, 16)
    assert plan_remesh(256, 16) == (1, 16, 16)
    # lose one host of 4 chips from a 512 fleet -> shrink data axis
    assert plan_remesh(508, 16) == (1, 16, 16)


def test_supervisor_poll(tmp_path):
    hosts = [f"h{i}" for i in range(4)]
    for i, h in enumerate(hosts):
        if h == "h3":
            continue                     # h3 never heartbeats
        Heartbeat(str(tmp_path), h).beat(1, 1.0, now=1000.0)
    sup = Supervisor(str(tmp_path), hosts, chips_per_host=64,
                     model_parallel=16, timeout_s=60)
    act = sup.poll(now=1001.0)
    assert act["action"] == "remesh"
    assert act["dead"] == ["h3"]
    assert act["new_mesh"] == (1, 8, 16)   # 192 chips -> data 8


def test_supervisor_all_healthy(tmp_path):
    hosts = ["h0", "h1"]
    for h in hosts:
        Heartbeat(str(tmp_path), h).beat(1, 1.0, now=1000.0)
    sup = Supervisor(str(tmp_path), hosts, timeout_s=60)
    assert sup.poll(now=1001.0)["action"] == "none"
