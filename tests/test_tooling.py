"""Tier-1 wiring for the dev tooling: the exhaustive circuit check
script and the machine-readable benchmark emission."""
import json
import os
import sys

import pytest

_ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), os.pardir)
sys.path.insert(0, os.path.join(_ROOT, "scripts"))


def test_dev_check_circuits_quick():
    """scripts/dev_check_circuits.py --quick is part of the tier-1 flow."""
    import dev_check_circuits
    assert dev_check_circuits.run_checks(quick=True)


def test_bench_json_writer(tmp_path):
    """run.py's JSON emission produces the BENCH_<section>.json layout
    future PRs read for the perf trajectory."""
    sys.path.insert(0, _ROOT)
    from benchmarks.run import _write_json
    results = {"formats": {"hobflops9": {"rne": {
        "seed_macs_per_s": 1.0, "chain4_macs_per_s": 1.6,
        "speedup_vs_seed": 1.6}}}}
    path = _write_json(str(tmp_path), "macs", results)
    assert os.path.basename(path) == "BENCH_macs.json"
    with open(path) as f:
        assert json.load(f) == results


def test_network_bench_smoke():
    """Tier-1 smoke of the multi-layer pipeline benchmark: a tiny
    bitslice-resident stack runs, matches the per-layer roundtrip
    bit-exactly, and yields the BENCH_network.json row layout."""
    sys.path.insert(0, _ROOT)
    from benchmarks.network import smoke
    row = smoke()
    for key in ("resident_macs_per_s", "roundtrip_macs_per_s",
                "speedup_vs_roundtrip", "macs"):
        assert key in row, row
    assert row["macs"] > 0


def test_serve_bench_smoke():
    """Tier-1 smoke of the lane-batched serve engine: a tiny graph
    serves 5 queued requests (one full wave + a ragged wave),
    bit-exact vs the per-request run, and reports engine stats."""
    sys.path.insert(0, _ROOT)
    from benchmarks.serve import smoke
    st = smoke()
    assert st["waves"] == 2 and st["images_served"] == 5
    assert st["runner_cache"]["misses"] >= 1
    assert 0.0 < st["mean_occupancy"] <= 1.0


def test_gates_chain_table_shape():
    """chain_table reports gates/MAC per lib with the fields the
    acceptance trajectory tracks."""
    sys.path.insert(0, _ROOT)
    from benchmarks.gates import LIBS, chain_table
    rows = chain_table(["hobflops8"], k=2)
    (row,) = rows
    for lib in LIBS:
        cell = row[lib]
        assert cell["chain_gates_per_mac"] < cell["mac_gates"]
        assert cell["saving_pct"] > 0
