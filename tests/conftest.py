import os
import sys

# Tests run on the real device set (1 CPU device) — the 512-device
# XLA_FLAGS override belongs to launch/dryrun.py ONLY.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
