import os
import sys

# Tests run on the real device set (1 CPU device) — the 512-device
# XLA_FLAGS override belongs to launch/dryrun.py ONLY.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def count_primitives(jx, name):
    """Occurrences of primitive ``name`` in a jaxpr, recursing into
    nested jaxprs (pjit bodies, scan/fori carriers).  Shared by the
    one-encode/one-decode invariant tests."""
    n = 0
    for e in jx.eqns:
        if str(e.primitive) == name:
            n += 1
        for p in e.params.values():
            for sub in (p if isinstance(p, (list, tuple)) else (p,)):
                inner = getattr(sub, "jaxpr", None)
                if inner is not None:
                    n += count_primitives(getattr(inner, "jaxpr", inner),
                                          name)
    return n
