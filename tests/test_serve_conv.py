"""Lane-batched serving engine (DESIGN.md §10).

Acceptance-level checks: every request served through a packed wave —
ragged final waves, heterogeneous mini-batch requests, bucket pad, and
the sharded path included — decodes bit-identical to ``graph.run`` on
that request alone; one encode + one decode per wave in the jaxpr; the
runner cache bounds compiled shapes to the bucket ladder; a seeded
``tune_conv_blocks`` disk cache is honored without running the sweep.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bitslice import stack_activations, split_activation
from repro.core.fpformat import FPFormat
from repro.kernels.conv2d_bitslice.network import NetworkGraph
from repro.kernels.conv2d_bitslice.ops import (decode_activations,
                                               encode_activations)
from repro.serve_conv import (ConvRequest, ConvServeEngine, RunnerCache,
                              ServeError, bucket_for, bucket_sizes,
                              derive_max_batch, pack_wave,
                              tuned_conv_blocks, unpack_wave, wave_mesh,
                              wave_sharded_runner)
from repro.serve_conv.cache import TUNE_CACHE_ENV, tune_cache_path, tune_key

F8 = FPFormat(5, 2)
F9 = FPFormat(5, 3)


def _rand(rng, shape, scale=1.0):
    return (rng.standard_normal(shape) * scale).astype(np.float32)


def _graph(rng, cin=4, width=8, fmt=F8):
    """Small serving graph: 3x3 conv -> pointwise -> maxpool."""
    g = NetworkGraph(fmt)
    c1 = g.conv("c1", g.input_name, _rand(rng, (3, 3, cin, width), 0.4),
                relu=True)
    c2 = g.conv("c2", c1, _rand(rng, (1, 1, width, width), 0.4),
                relu=True)
    g.output(g.maxpool2d("head", c2, window=2))
    return g


# ---------------------------------------------------------------------------
# lanes: pack/unpack
# ---------------------------------------------------------------------------
def test_pack_unpack_roundtrip_ragged():
    """Heterogeneous request sizes pack contiguously, pad to the
    bucket, and slice back exactly (rank restored for 3-d requests)."""
    rng = np.random.default_rng(0)
    imgs = [_rand(rng, (5, 5, 3)), _rand(rng, (2, 5, 5, 3)),
            _rand(rng, (5, 5, 3))]
    batch, plan = pack_wave(imgs, bucket=8)
    assert batch.shape == (8, 5, 5, 3)
    assert plan.filled == 4 and plan.occupancy == 0.5
    np.testing.assert_array_equal(batch[4:], 0.0)
    back = unpack_wave(batch, plan)
    np.testing.assert_array_equal(back[0], imgs[0])
    np.testing.assert_array_equal(back[1], imgs[1])
    np.testing.assert_array_equal(back[2], imgs[2])
    assert back[0].shape == (5, 5, 3) and back[1].shape == (2, 5, 5, 3)


def test_pack_wave_validates_geometry():
    rng = np.random.default_rng(1)
    with pytest.raises(ValueError, match="geometry"):
        pack_wave([_rand(rng, (4, 4, 3)), _rand(rng, (5, 5, 3))], 4)
    with pytest.raises(ServeError, match="bucket"):
        pack_wave([_rand(rng, (3, 4, 4, 3))], 2)


def test_bucket_ladder():
    assert bucket_sizes(8) == (1, 2, 4, 8)
    assert bucket_sizes(6) == (1, 2, 4, 6)
    assert bucket_for(3, (1, 2, 4, 8)) == 4
    with pytest.raises(ValueError, match="exceed"):
        bucket_for(9, (1, 2, 4, 8))
    assert derive_max_batch((8, 8, 4)) == 64
    assert derive_max_batch((64, 64, 4)) == 1


# ---------------------------------------------------------------------------
# plane-level stack/split
# ---------------------------------------------------------------------------
def test_stack_split_activations_bit_exact():
    """Plane-level wave coalescing: stacking per-request carriers
    equals encoding the stacked batch; splitting recovers each request
    bit-exactly."""
    rng = np.random.default_rng(2)
    imgs = _rand(rng, (4, 6, 6, 5), 2.0)
    a = encode_activations(jnp.asarray(imgs[:1]), F9)
    b = encode_activations(jnp.asarray(imgs[1:]), F9)
    s = stack_activations([a, b])
    assert s.shape == (4, 6, 6, 5)
    full = encode_activations(jnp.asarray(imgs), F9)
    np.testing.assert_array_equal(np.asarray(decode_activations(s)),
                                  np.asarray(decode_activations(full)))
    pa, pb = split_activation(s, [1, 3])
    np.testing.assert_array_equal(np.asarray(decode_activations(pa)),
                                  np.asarray(decode_activations(a)))
    np.testing.assert_array_equal(np.asarray(decode_activations(pb)),
                                  np.asarray(decode_activations(b)))


# ---------------------------------------------------------------------------
# engine: wave admission + bit-exactness
# ---------------------------------------------------------------------------
def test_engine_bit_exact_vs_per_request():
    """Tentpole acceptance: 5 heterogeneous requests served over a full
    wave + a ragged final wave all decode bit-identical to graph.run on
    each request alone (bucket pad included)."""
    rng = np.random.default_rng(3)
    g = _graph(rng)
    eng = ConvServeEngine(g, (8, 8, 4), max_batch=4)
    reqs = [ConvRequest(0, _rand(rng, (8, 8, 4))),
            ConvRequest(1, _rand(rng, (2, 8, 8, 4))),
            ConvRequest(2, _rand(rng, (8, 8, 4))),        # wave 0: 4 imgs
            ConvRequest(3, _rand(rng, (8, 8, 4))),
            ConvRequest(4, _rand(rng, (2, 8, 8, 4)))]     # wave 1: ragged
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert [r.rid for r in done] == [0, 1, 2, 3, 4]
    assert eng.waves == 2 and eng.images_served == 7
    assert eng.wave_occupancy == [1.0, 0.75]        # 4/4, then 3 in a 4
    for r in done:
        batched = r.image[None] if r.image.ndim == 3 else r.image
        solo = np.asarray(g.run(batched))
        solo = solo[0] if r.image.ndim == 3 else solo
        np.testing.assert_array_equal(np.asarray(r.out), solo,
                                      err_msg=f"request {r.rid}")
        assert r.done and r.latency_s > 0
    st = eng.stats()
    assert st["images_per_s"] > 0 and st["macs_per_s"] > 0


def test_engine_one_encode_decode_per_wave():
    """A packed wave is one resident call: exactly one f32->i32 bitcast
    (entry encode) and one i32->f32 (exit decode) in the wave jaxpr."""
    from conftest import count_primitives
    rng = np.random.default_rng(4)
    g = _graph(rng)
    eng = ConvServeEngine(g, (8, 8, 4), max_batch=4)
    runner, _ = eng.executor._runner(g, (8, 8, 4), 4, None)
    jaxpr = jax.make_jaxpr(runner)(np.zeros((4, 8, 8, 4), np.float32))
    assert count_primitives(jaxpr.jaxpr, "bitcast_convert_type") == 2


def test_engine_rejects_oversized_and_misshaped():
    rng = np.random.default_rng(5)
    g = _graph(rng)
    eng = ConvServeEngine(g, (8, 8, 4), max_batch=2)
    with pytest.raises(ValueError, match="max_batch"):
        eng.submit(ConvRequest(0, _rand(rng, (3, 8, 8, 4))))
    with pytest.raises(ValueError, match="geometry"):
        eng.submit(ConvRequest(1, _rand(rng, (6, 6, 4))))


def test_runner_cache_buckets_bound_compiles():
    """Wave sizes 1/2/3/4/1 touch only buckets {1, 2, 4}: three misses,
    then hits — the compiled-program count is the bucket ladder, not
    the traffic mix."""
    rng = np.random.default_rng(6)
    g = _graph(rng)
    cache = RunnerCache()
    eng = ConvServeEngine(g, (8, 8, 4), max_batch=4, runner_cache=cache)
    for n in (1, 2, 3, 4, 1):
        for i in range(n):
            eng.submit(ConvRequest(i, _rand(rng, (8, 8, 4))))
        eng.run_wave()
    assert len(cache) == 3                       # buckets 1, 2, 4
    assert cache.misses == 3 and cache.hits == 2
    st = eng.stats()
    assert st["runner_cache"] == {"size": 3, "hits": 2, "misses": 3,
                                  "evictions": 0}


def test_runner_cache_key_separates_graphs():
    rng = np.random.default_rng(7)
    g1, g2 = _graph(rng), _graph(rng, fmt=F9)
    cache = RunnerCache()
    assert g1.signature() != g2.signature()
    assert cache.key(g1, (8, 8, 4), 2) != cache.key(g2, (8, 8, 4), 2)
    # same structure, different weight values: same compiled runner key
    g3 = _graph(np.random.default_rng(99))
    assert g1.signature() == g3.signature()


# ---------------------------------------------------------------------------
# tune persistence
# ---------------------------------------------------------------------------
def test_tune_cache_seeded_is_honored(tmp_path, monkeypatch):
    """A seeded disk cache short-circuits the sweep entirely: the
    stored blocks come back verbatim and tune_conv_blocks is never
    called."""
    rng = np.random.default_rng(8)
    img = _rand(rng, (1, 6, 6, 4))
    kern = _rand(rng, (3, 3, 4, 8), 0.3)
    path = str(tmp_path / "tune.json")
    key = tune_key(img.shape, kern, F8)
    seeded = {"p_block": 8, "m_block": 32, "c_block": 36, "c_unroll": 2}
    with open(path, "w") as f:
        json.dump({key: {"blocks": seeded, "backend": "jnp",
                         "seconds_per_call": 1.0}}, f)

    def boom(*a, **k):                            # pragma: no cover
        raise AssertionError("sweep ran despite a seeded cache")
    monkeypatch.setattr("repro.serve_conv.cache.tune_conv_blocks", boom)
    blocks, dt = tuned_conv_blocks(img, kern, fmt=F8, path=path)
    assert blocks == seeded and dt is None


def test_tune_cache_stale_backend_warns_and_retunes(tmp_path):
    """An entry without a backend tag (pre-versioning file, or a
    hand-seeded one) is stale: it is never reused silently — a warning
    fires, the sweep re-runs, and the fresh tagged winner replaces the
    entry."""
    rng = np.random.default_rng(8)
    img = _rand(rng, (1, 6, 6, 4))
    kern = _rand(rng, (1, 1, 4, 8), 0.3)
    path = str(tmp_path / "tune.json")
    cands = [{"c_unroll": 1, "m_block": 8}]
    key = tune_key(img.shape, kern, F8, candidates=cands)
    stale = {"p_block": 1, "m_block": 1, "c_block": 1, "c_unroll": 1}
    with open(path, "w") as f:
        json.dump({key: {"blocks": stale, "seconds_per_call": 1.0}}, f)
    with pytest.warns(RuntimeWarning, match="stale"):
        blocks, dt = tuned_conv_blocks(img, kern, fmt=F8, path=path,
                                       iters=1, candidates=cands)
    assert dt is not None                     # the sweep actually ran
    entry = json.load(open(path))[key]
    assert entry["backend"] == "jnp"          # replaced, now tagged
    # tagged entry is honored again on the next call
    blocks2, dt2 = tuned_conv_blocks(img, kern, fmt=F8, path=path,
                                     candidates=cands)
    assert blocks2 == blocks and dt2 is None


def test_tune_cache_miss_runs_and_persists(tmp_path):
    rng = np.random.default_rng(9)
    img = _rand(rng, (1, 6, 6, 4))
    kern = _rand(rng, (1, 1, 4, 8), 0.3)
    path = str(tmp_path / "tune.json")
    cands = [{"c_unroll": 1, "m_block": 8}]
    blocks, dt = tuned_conv_blocks(img, kern, fmt=F8, path=path, iters=1,
                                   candidates=cands)
    assert dt is not None and os.path.exists(path)
    # second call with the same candidate set: pure disk hit
    blocks2, dt2 = tuned_conv_blocks(img, kern, fmt=F8, path=path,
                                     candidates=cands)
    assert blocks2 == blocks and dt2 is None
    # a different candidate set is a different problem: no false hit
    assert tune_key(img.shape, kern, F8, candidates=cands) != \
        tune_key(img.shape, kern, F8)


def test_tune_cache_env_var_override(monkeypatch, tmp_path):
    monkeypatch.setenv(TUNE_CACHE_ENV, str(tmp_path / "env.json"))
    assert tune_cache_path() == str(tmp_path / "env.json")
    assert tune_cache_path("/explicit.json") == "/explicit.json"


# ---------------------------------------------------------------------------
# sharding
# ---------------------------------------------------------------------------
def test_sharded_wave_bit_exact_single_device():
    """The shard_map path (1-device CPU mesh) equals the unsharded wave
    bit-for-bit, end to end through the engine."""
    rng = np.random.default_rng(10)
    g = _graph(rng)
    imgs = _rand(rng, (4, 8, 8, 4))
    runner = wave_sharded_runner(g, wave_mesh())
    np.testing.assert_array_equal(np.asarray(runner(imgs)),
                                  np.asarray(g.run(imgs)))
    eng = ConvServeEngine(g, (8, 8, 4), max_batch=4, mesh=wave_mesh())
    for i in range(4):
        eng.submit(ConvRequest(i, imgs[i]))
    done = eng.run()
    for i, r in enumerate(done):
        np.testing.assert_array_equal(np.asarray(r.out),
                                      np.asarray(g.run(imgs[i:i + 1]))[0])


_MULTIDEV_SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import numpy as np
import jax
from repro.core.fpformat import FPFormat
from repro.kernels.conv2d_bitslice.network import NetworkGraph
from repro.serve_conv import wave_mesh, wave_sharded_runner

assert len(jax.devices()) == 2
rng = np.random.default_rng(0)
g = NetworkGraph(FPFormat(5, 2))
c1 = g.conv("c1", g.input_name,
            (rng.standard_normal((3, 3, 3, 4)) * 0.4).astype(np.float32),
            relu=True)
g.output(c1)
imgs = rng.standard_normal((4, 6, 6, 3)).astype(np.float32)
got = np.asarray(wave_sharded_runner(g, wave_mesh())(imgs))
np.testing.assert_array_equal(got, np.asarray(g.run(imgs)))
print("MULTIDEV-OK")
"""


def test_sharded_wave_bit_exact_two_devices():
    """A real 2-device split of the wave batch (forced host devices in
    a subprocess: the in-process device set must stay 1) is bit-exact
    vs single-device."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    out = subprocess.run([sys.executable, "-c", _MULTIDEV_SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr
    assert "MULTIDEV-OK" in out.stdout


# ---------------------------------------------------------------------------
# summary / signature satellites
# ---------------------------------------------------------------------------
def test_summary_snapshot():
    """NetworkGraph.summary emits the exact per-node table the engine
    logs at startup."""
    rng = np.random.default_rng(11)
    g = _graph(rng)
    expected = "\n".join([
        "node   op         format    out shape  MACs",
        "-------------------------------------------",
        "input  input      e5f2/10b  1x8x8x4    -",
        "c1     conv       e5f3/11b  1x8x8x8    18,432",
        "c2     conv       e5f3/11b  1x8x8x8    4,096",
        "head   maxpool2d  e5f3/11b  1x4x4x8    -",
        "total                                  22,528",
    ])
    assert g.summary((1, 8, 8, 4)) == expected


def test_signature_ignores_pruned_dead_branches():
    """Two graphs whose live node sets match share a signature (and
    therefore a RunnerCache entry) even when one carried a dead branch
    that output() pruned from the compiled runner."""
    def build(dead):
        rng = np.random.default_rng(14)
        g = NetworkGraph(F8)
        c1 = g.conv("c1", g.input_name, _rand(rng, (1, 1, 4, 8), 0.4))
        if dead:
            g.conv("dead", g.input_name, _rand(rng, (3, 3, 4, 8), 0.4))
        return g.output(c1)
    assert build(False).signature() == build(True).signature()


def test_conv_launch_blocks_threaded_and_bit_exact():
    """A tune_conv_blocks winner pinned via conv(blocks=...) reaches
    the kernel launch of both runners: outputs stay bit-exact (launch
    geometry never changes values) and the compiled structure —
    signature — reflects the override."""
    def build(blocks):
        rng = np.random.default_rng(15)
        g = NetworkGraph(F8)
        c1 = g.conv("c1", g.input_name, _rand(rng, (3, 3, 4, 8), 0.4),
                    relu=True, blocks=blocks)
        return g.output(c1)
    img = _rand(np.random.default_rng(16), (1, 6, 6, 4))
    base, tuned = build(None), build({"c_unroll": 2, "m_block": 8})
    assert base.signature() != tuned.signature()
    assert tuned._nodes["c1"].blocks == (("c_unroll", 2), ("m_block", 8))
    want = np.asarray(base.run(img))
    np.testing.assert_array_equal(np.asarray(tuned.run(img)), want)
    np.testing.assert_array_equal(np.asarray(tuned.run_roundtrip(img)),
                                  want)
    from repro.kernels.conv2d_bitslice.network import GraphValidationError
    with pytest.raises(GraphValidationError, match="unknown launch"):
        build({"bogus": 1})


def test_signature_stability_and_sensitivity():
    rng = np.random.default_rng(12)
    g = _graph(rng)
    assert g.signature() == g.signature()
    # strided variant differs structurally
    g2 = NetworkGraph(F8)
    c1 = g2.conv("c1", g2.input_name, _rand(rng, (3, 3, 4, 8), 0.4),
                 relu=True, stride=2)
    c2 = g2.conv("c2", c1, _rand(rng, (1, 1, 8, 8), 0.4), relu=True)
    g2.output(g2.maxpool2d("head", c2, window=2))
    assert g.signature() != g2.signature()
