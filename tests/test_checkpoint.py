"""Checkpoint store: roundtrip, atomic commit, async manager, integrity,
elastic (re-sharded) restore."""
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, latest_step,
                              restore_checkpoint, save_checkpoint)


def tree(key=0):
    rng = np.random.default_rng(key)
    return {
        "params": {"w": jnp.asarray(rng.standard_normal((8, 16)),
                                    jnp.float32),
                   "b": jnp.asarray(rng.standard_normal(16),
                                    jnp.bfloat16)},
        "step": jnp.asarray(7, jnp.int32),
    }


def abstract(t):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)


def test_roundtrip(tmp_path):
    state = tree()
    save_checkpoint(tmp_path, 3, state)
    assert latest_step(tmp_path) == 3
    restored = restore_checkpoint(tmp_path, 3, abstract(state))
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_gc_keeps_last_k(tmp_path):
    state = tree()
    for s in range(5):
        save_checkpoint(tmp_path, s, state, keep=2)
    steps = sorted(int(p.name.split("_")[1])
                   for p in pathlib.Path(tmp_path).glob("step_*"))
    assert steps == [3, 4]


def test_manager_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    state = tree()
    mgr.save(1, state)
    mgr.wait()
    step, restored = mgr.restore_latest(abstract(state))
    assert step == 1
    np.testing.assert_array_equal(
        np.asarray(restored["params"]["w"]),
        np.asarray(state["params"]["w"]))


def test_integrity_check(tmp_path):
    state = tree()
    path = save_checkpoint(tmp_path, 0, state)
    # corrupt one chunk
    chunk = next(p for p in path.glob("*.npy"))
    raw = bytearray(chunk.read_bytes())
    raw[-1] ^= 0xFF
    chunk.write_bytes(bytes(raw))
    with pytest.raises(IOError):
        restore_checkpoint(tmp_path, 0, abstract(state), verify=True)


def test_elastic_restore_onto_sharded_mesh(tmp_path):
    """Save unsharded, restore onto a (1,1) named mesh — the slice
    reader must serve arbitrary index requests."""
    from jax.sharding import NamedSharding, PartitionSpec
    state = tree()
    save_checkpoint(tmp_path, 2, state)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sh = {
        "params": {"w": NamedSharding(mesh, PartitionSpec("data", "model")),
                   "b": NamedSharding(mesh, PartitionSpec("model"))},
        "step": NamedSharding(mesh, PartitionSpec()),
    }
    restored = restore_checkpoint(tmp_path, 2, abstract(state), sh)
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(state["params"]["w"]))


def test_crash_leaves_no_partial_checkpoint(tmp_path):
    state = tree()
    save_checkpoint(tmp_path, 1, state)
    tmp = pathlib.Path(tmp_path) / "step_2.tmp"
    tmp.mkdir()
    (tmp / "garbage.npy").write_bytes(b"xx")   # simulated dead writer
    assert latest_step(tmp_path) == 1          # .tmp is invisible


def test_train_restart_resumes(tmp_path):
    from repro.configs import smoke_config
    from repro.launch.train import train_loop
    from repro.models.config import ShapeConfig
    cfg = smoke_config("qwen2-0.5b")
    shape = ShapeConfig("t", 32, 2, "train")
    # run 10 steps, checkpoint every 4, "crash" at 9
    train_loop(cfg, shape, steps=10, ckpt_dir=str(tmp_path),
               ckpt_every=4, kill_at=9, log_every=1000,
               print_fn=lambda *a: None)
    assert latest_step(tmp_path) == 7
    logs = []
    train_loop(cfg, shape, steps=10, ckpt_dir=str(tmp_path),
               ckpt_every=4, log_every=1000, print_fn=logs.append)
    assert any("resuming at 8" in str(m) for m in logs)
