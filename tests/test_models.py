"""Model-stack correctness: MoE vs dense reference, SSD vs sequential
recurrence, prefill/decode consistency for every assigned architecture."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, smoke_config
from repro.models import (decode_step, forward_logits, lm_loss,
                          model_schema, prefill)
from repro.models.mamba import (mamba, mamba_decode, mamba_schema,
                                ssd_chunked, ssd_reference)
from repro.models.moe import moe, moe_dense_ref, moe_schema
from repro.models.schema import init_params, param_count

KEY = jax.random.PRNGKey(0)


def make_batch(cfg, B, S, train=True, key=KEY):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    if train:
        batch["labels"] = jax.random.randint(
            jax.random.fold_in(key, 1), (B, S), 0, cfg.vocab)
    if cfg.frontend != "none" and cfg.family != "encdec":
        batch["prefix"] = jax.random.normal(
            key, (B, cfg.num_prefix, cfg.frontend_dim))
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(key, (B, S, cfg.frontend_dim))
    return batch


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------
def test_moe_matches_dense_ref_when_no_drops():
    cfg = dataclasses.replace(smoke_config("olmoe-1b-7b"),
                              moe_capacity_factor=8.0)
    p = init_params(moe_schema(cfg), KEY)
    x = jax.random.normal(KEY, (2, 16, cfg.d_model))
    y, aux = moe(p, x, cfg)
    y_ref = moe_dense_ref(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-5)
    assert float(aux) > 0


def test_moe_capacity_drops_reduce_output():
    cfg = dataclasses.replace(smoke_config("olmoe-1b-7b"),
                              moe_capacity_factor=0.25)
    p = init_params(moe_schema(cfg), KEY)
    x = jax.random.normal(KEY, (2, 16, cfg.d_model))
    y, _ = moe(p, x, cfg)
    y_full = moe_dense_ref(p, x, cfg)
    # dropped tokens produce zero contribution -> strictly less energy
    assert float(jnp.sum(y ** 2)) < float(jnp.sum(y_full ** 2))
    assert bool(jnp.all(jnp.isfinite(y)))


# ---------------------------------------------------------------------------
# SSD
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("chunk", [8, 16, 64])
def test_ssd_chunked_vs_sequential(chunk):
    rng = np.random.default_rng(chunk)
    B, S, H, P, N = 2, 48, 3, 4, 8
    xdt = rng.standard_normal((B, S, H, P)).astype(np.float32)
    dA = -np.abs(rng.standard_normal((B, S, H))).astype(np.float32)
    Bm = rng.standard_normal((B, S, N)).astype(np.float32)
    Cm = rng.standard_normal((B, S, N)).astype(np.float32)
    y, h = ssd_chunked(jnp.asarray(xdt), jnp.asarray(dA),
                       jnp.asarray(Bm), jnp.asarray(Cm), chunk)
    y_ref, h_ref = ssd_reference(xdt, dA, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h), h_ref, rtol=2e-4, atol=2e-4)


def test_ssd_carried_state():
    """Splitting a sequence and carrying h0 equals one long scan."""
    rng = np.random.default_rng(1)
    B, S, H, P, N = 1, 32, 2, 4, 8
    xdt = rng.standard_normal((B, S, H, P)).astype(np.float32)
    dA = -np.abs(rng.standard_normal((B, S, H))).astype(np.float32)
    Bm = rng.standard_normal((B, S, N)).astype(np.float32)
    Cm = rng.standard_normal((B, S, N)).astype(np.float32)
    y_full, h_full = ssd_chunked(xdt, dA, Bm, Cm, 8)
    y1, h1 = ssd_chunked(xdt[:, :16], dA[:, :16], Bm[:, :16],
                         Cm[:, :16], 8)
    y2, h2 = ssd_chunked(xdt[:, 16:], dA[:, 16:], Bm[:, 16:],
                         Cm[:, 16:], 8, h0=h1)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(y2),
                               np.asarray(y_full[:, 16:]),
                               rtol=1e-5, atol=1e-5)


def test_mamba_decode_matches_full():
    cfg = smoke_config("mamba2-2.7b")
    p = init_params(mamba_schema(cfg), KEY)
    x = jax.random.normal(KEY, (2, 33, cfg.d_model)) * 0.5
    y_full, _ = mamba(p, x, cfg)
    _, st = mamba(p, x[:, :32], cfg)
    y_dec, _ = mamba_decode(p, x[:, 32:33], cfg, st)
    np.testing.assert_allclose(np.asarray(y_dec[:, 0]),
                               np.asarray(y_full[:, 32]),
                               rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# Per-arch smoke: forward + loss finite, gradients flow
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_arch_smoke_train_step(arch):
    cfg = smoke_config(arch)
    schema = model_schema(cfg)
    assert param_count(schema) > 0
    params = init_params(schema, KEY)
    batch = make_batch(cfg, 2, 64)
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: lm_loss(p, batch, cfg), has_aux=True)(params)
    assert np.isfinite(float(loss))
    gnorm = sum(float(jnp.sum(g ** 2)) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_arch_prefill_decode_consistency(arch):
    """decode(prefill(S-1)) logits == full-forward logits at position S-1.

    f32 compute isolates *path* equivalence from bf16 noise; the MoE
    capacity factor is raised so token drops can't differ between the
    S-1-token and S-token routing problems."""
    cfg = dataclasses.replace(smoke_config(arch),
                              compute_dtype="float32",
                              moe_capacity_factor=8.0)
    params = init_params(model_schema(cfg), KEY)
    B, S = 2, 32
    batch = make_batch(cfg, B, S, train=False)
    lg_full, _ = forward_logits(params, batch, cfg, mode="prefill")
    pre_batch = dict(batch, tokens=batch["tokens"][:, :S - 1])
    cache, lg_pre, length = prefill(params, pre_batch, cfg,
                                    max_len=S + cfg.num_prefix,
                                    dtype=jnp.float32)
    lg_dec, _ = decode_step(params, batch["tokens"][:, S - 1], cache,
                            jnp.asarray(length, jnp.int32), cfg)
    np.testing.assert_allclose(np.asarray(lg_dec),
                               np.asarray(lg_full[:, -1]),
                               rtol=3e-2, atol=3e-2)
    # prefill's own last-position logits match the full forward too
    np.testing.assert_allclose(np.asarray(lg_pre),
                               np.asarray(lg_full[:, -2]),
                               rtol=3e-2, atol=3e-2)


def test_scan_period_detection():
    jamba = smoke_config("jamba-v0.1-52b")
    assert jamba.scan_period() == 8
    assert smoke_config("llama3-405b").scan_period() == 1
    kinds = jamba.layer_kinds()
    assert sum(1 for a, _ in kinds if a) == jamba.n_layers // 8
