"""Training substrate: optimizer math, microbatch equivalence, loss
actually decreases, quantized-serving consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.data.pipeline import SyntheticLM, make_batch
from repro.models import lm_loss, model_schema
from repro.models.config import ShapeConfig
from repro.models.schema import init_params
from repro.optim import OptConfig, adamw_init, adamw_update, lr_at
from repro.train.step import TrainConfig, init_state, make_train_step

KEY = jax.random.PRNGKey(0)


def test_lr_schedule():
    opt = OptConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                    min_lr_ratio=0.1)
    assert float(lr_at(opt, 0)) < float(lr_at(opt, 9))
    np.testing.assert_allclose(float(lr_at(opt, 10)), 1e-3, rtol=1e-2)
    assert float(lr_at(opt, 99)) < 2e-4  # decayed near min
    assert float(lr_at(opt, 200)) >= 1e-4 * 0.99  # floor


def test_adamw_matches_reference():
    """One AdamW step vs a hand-rolled numpy reference."""
    opt = OptConfig(lr=1e-2, beta1=0.9, beta2=0.999, eps=1e-8,
                    weight_decay=0.01, clip=1e9, warmup_steps=0,
                    min_lr_ratio=1.0)
    rng = np.random.default_rng(0)
    p = {"w": jnp.asarray(rng.standard_normal(8), jnp.float32)}
    g = {"w": jnp.asarray(rng.standard_normal(8), jnp.float32)}
    st = adamw_init(p, opt)
    new_p, new_st, _ = adamw_update(p, g, st, 0, opt)
    m = 0.1 * np.asarray(g["w"])
    v = 0.001 * np.asarray(g["w"]) ** 2
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.999)
    want = (np.asarray(p["w"]) - 1e-2 * (mhat / (np.sqrt(vhat) + 1e-8)
            + 0.01 * np.asarray(p["w"])))
    np.testing.assert_allclose(np.asarray(new_p["w"]), want, rtol=1e-5)


def test_grad_clipping():
    opt = OptConfig(clip=1.0, weight_decay=0.0)
    p = {"w": jnp.ones(4)}
    g = {"w": jnp.full(4, 100.0)}
    st = adamw_init(p, opt)
    _, _, metrics = adamw_update(p, g, st, 0, opt)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0)


def test_microbatch_equivalence():
    """n_micro=2 produces (nearly) the same update as n_micro=1."""
    cfg = smoke_config("qwen3-4b")
    tc1 = TrainConfig(n_micro=1)
    tc2 = TrainConfig(n_micro=2)
    state1 = init_state(cfg, tc1, KEY)
    state2 = jax.tree.map(lambda x: x, state1)
    ds = SyntheticLM(vocab=cfg.vocab, seq_len=32, global_batch=4)
    batch = jax.tree.map(jnp.asarray, make_batch(ds, 0))
    s1, m1 = jax.jit(make_train_step(cfg, tc1))(state1, batch)
    s2, m2 = jax.jit(make_train_step(cfg, tc2))(state2, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    for a, b in zip(jax.tree.leaves(s1["params"]),
                    jax.tree.leaves(s2["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("arch,steps,margin", [
    ("qwen2-0.5b", 25, 0.2),
    ("mamba2-2.7b", 25, 0.2),
    ("olmoe-1b-7b", 45, 0.1),   # 64-expert routing learns slower
])
def test_loss_decreases(arch, steps, margin):
    from repro.launch.train import train_loop
    cfg = smoke_config(arch)
    shape = ShapeConfig("t", 64, 4, "train")
    _, losses = train_loop(cfg, shape, steps=steps,
                           tc=TrainConfig(opt=OptConfig(
                               lr=1e-2, warmup_steps=5,
                               total_steps=steps)),
                           log_every=1000, print_fn=lambda *a: None)
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    assert last < first - margin, (first, last)


def test_quantized_forward_close_to_f32():
    from repro.quant.apply import quantize_params
    cfg = smoke_config("qwen3-4b")
    params = init_params(model_schema(cfg), KEY)
    B, S = 2, 32
    batch = {"tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab),
             "labels": jax.random.randint(KEY, (B, S), 0, cfg.vocab)}
    from repro.models import forward_logits
    lg_f32, _ = forward_logits(params, batch, cfg, mode="prefill")
    qparams, deq = quantize_params(params, cfg, "hobflops16")
    lg_q, _ = forward_logits(qparams, batch, cfg, mode="prefill",
                             deq=deq)
    # hobflops16 (e5m10) weight storage ~ half-precision weights
    err = np.abs(np.asarray(lg_q) - np.asarray(lg_f32)).max()
    scale = np.abs(np.asarray(lg_f32)).max()
    assert err < 0.05 * scale, (err, scale)


def test_quantized_bytes_accounting():
    from repro.quant.apply import quantize_params, quantized_bytes
    cfg = smoke_config("gemma-2b")
    params = init_params(model_schema(cfg), KEY)
    qp, _ = quantize_params(params, cfg, "hobflops9")
    qb, db = quantized_bytes(qp)
    assert qb > 0 and db > 0
    # 9-bit storage ~= 9/16 of bf16 plus per-layer scale overhead
    assert qb < 0.60 * db


def test_quantized_decode_untied_logits():
    """Full serve path with bitplane weights incl. an untied (quantized)
    logits head."""
    from repro.models import decode_step, prefill
    from repro.quant.apply import quantize_params
    cfg = smoke_config("llama3-405b")   # untied -> logits head quantized
    params = init_params(model_schema(cfg), KEY)
    qp, deq = quantize_params(params, cfg, "hobflops9")
    batch = {"tokens": jax.random.randint(KEY, (2, 16), 0, cfg.vocab)}
    cache, lg, length = prefill(qp, batch, cfg, max_len=20, deq=deq)
    assert bool(jnp.all(jnp.isfinite(lg)))
    tok = jnp.argmax(lg, -1).astype(jnp.int32)
    lg2, _ = decode_step(qp, tok, cache, jnp.asarray(length, jnp.int32),
                         cfg, deq=deq)
    assert bool(jnp.all(jnp.isfinite(lg2)))


def test_bitplane2d_roundtrip():
    from repro.kernels.dequant_matmul.ops import pack_weights
    from repro.quant.storage import dequantize, quantize
    from repro.core.fpformat import StorageFormat
    rng = np.random.default_rng(0)
    w = rng.standard_normal((48, 64)).astype(np.float32)
    sfmt = StorageFormat(5, 3)
    qt2d = pack_weights(w, sfmt)          # bitplane2d layout
    qtfl = quantize(w, sfmt, "bitplane")  # flat layout
    np.testing.assert_array_equal(np.asarray(dequantize(qt2d)),
                                  np.asarray(dequantize(qtfl)))


def test_abstract_quantize_matches_real():
    """Abstract quantized tree has the same structure/shapes as the
    dry-run expects (bitplane2d leaves, per-layer scales)."""
    from repro.models.schema import abstract_params
    from repro.quant.apply import abstract_quantize_params
    from repro.quant.storage import QuantizedTensor
    cfg = smoke_config("llama3-405b")
    ab = abstract_quantize_params(
        abstract_params(model_schema(cfg)), cfg, "hobflops9")
    wq = ab["blocks"]["b0"]["attn"]["wq"]
    assert isinstance(wq, QuantizedTensor)
    L = cfg.n_layers
    assert wq.data.shape[0] == L and wq.data.shape[1] == 9
    assert wq.scale.shape == (L,)
