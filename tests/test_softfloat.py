"""The word-parallel softfloat oracle vs true float64 arithmetic.

For small formats every operand pair is exhaustively enumerated; the
f64 product/sum of two small-format values is exact in f64, so
``encode(decode(x) op decode(y))`` is the ground truth the FloPoCo-
semantics implementation must match (modulo flush-to-zero/saturate,
which encode() applies identically).
"""
import numpy as np
import pytest

from repro.core import softfloat as sf
from repro.core.fpformat import (EXC_INF, EXC_NAN, EXC_NORMAL, EXC_ZERO,
                                 RNE, RTZ, FPFormat)


def canonical_codes(fmt, specials=True):
    codes = []
    if specials:
        for exc, signs in ((EXC_ZERO, (0, 1)), (EXC_INF, (0, 1)),
                           (EXC_NAN, (0,))):
            for s in signs:
                codes.append(int(sf.pack(exc, s, 0, 0, fmt)))
    n = 2 * (1 << fmt.w_e) * (1 << fmt.w_f)
    sign = np.repeat([0, 1], n // 2)
    exp = np.tile(np.repeat(np.arange(1 << fmt.w_e), 1 << fmt.w_f), 2)
    frac = np.tile(np.arange(1 << fmt.w_f), 2 * (1 << fmt.w_e))
    codes.extend(sf.pack(np.full(n, EXC_NORMAL), sign, exp, frac, fmt))
    return np.array(codes, dtype=np.int64)


@pytest.mark.parametrize("fmt", [FPFormat(3, 2), FPFormat(4, 2),
                                 FPFormat(2, 3)])
def test_encode_decode_roundtrip(fmt):
    codes = canonical_codes(fmt, specials=False)
    vals = sf.decode(codes, fmt)
    again = sf.encode(vals, fmt)
    np.testing.assert_array_equal(codes, again)


@pytest.mark.parametrize("rounding", [RNE, RTZ])
def test_mul_matches_f64(rounding):
    fmt = FPFormat(3, 2)
    fmt_out = fmt.mult_out()
    xs = canonical_codes(fmt, specials=False)
    X = np.repeat(xs, len(xs))
    Y = np.tile(xs, len(xs))
    got = sf.fp_mul(X, Y, fmt, fmt_out, rounding)
    want = sf.encode(sf.decode(X, fmt) * sf.decode(Y, fmt), fmt_out,
                     rounding)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("rounding", [RNE, RTZ])
def test_add_matches_f64(rounding):
    fmt = FPFormat(3, 3)
    xs = canonical_codes(fmt, specials=False)
    X = np.repeat(xs, len(xs))
    Y = np.tile(xs, len(xs))
    got = sf.fp_add(X, Y, fmt, rounding)
    s = sf.decode(X, fmt) + sf.decode(Y, fmt)   # exact in f64
    want = sf.encode(s, fmt, rounding)
    # exact-cancellation signs: FloPoCo returns +0, encode(0.0) gives +0
    np.testing.assert_array_equal(got, want)


def test_special_values_mul():
    fmt = FPFormat(4, 3)
    fo = fmt.mult_out()
    inf = sf.pack(EXC_INF, 0, 0, 0, fmt)
    zero = sf.pack(EXC_ZERO, 0, 0, 0, fmt)
    nan = sf.pack(EXC_NAN, 0, 0, 0, fmt)
    one = sf.encode(1.0, fmt)
    # inf * 0 = nan ; inf * 1 = inf ; nan * x = nan ; 0 * 1 = 0
    assert sf.unpack(sf.fp_mul(inf, zero, fmt, fo), fo)[0] == EXC_NAN
    assert sf.unpack(sf.fp_mul(inf, one, fmt, fo), fo)[0] == EXC_INF
    assert sf.unpack(sf.fp_mul(nan, one, fmt, fo), fo)[0] == EXC_NAN
    assert sf.unpack(sf.fp_mul(zero, one, fmt, fo), fo)[0] == EXC_ZERO


def test_special_values_add():
    fmt = FPFormat(4, 3)
    inf = sf.pack(EXC_INF, 0, 0, 0, fmt)
    ninf = sf.pack(EXC_INF, 1, 0, 0, fmt)
    one = sf.encode(1.0, fmt)
    # inf + (-inf) = nan ; inf + 1 = inf
    assert sf.unpack(sf.fp_add(inf, ninf, fmt), fmt)[0] == EXC_NAN
    assert sf.unpack(sf.fp_add(inf, one, fmt), fmt)[0] == EXC_INF


def test_encode_jnp_matches_numpy():
    import jax.numpy as jnp
    fmt = FPFormat(5, 3)
    rng = np.random.default_rng(0)
    x = np.concatenate([
        rng.standard_normal(512) * 10.0 ** rng.integers(-3, 3, 512),
        [0.0, -0.0, np.inf, -np.inf, np.nan, 65504.0, 1e-30]])
    got = np.asarray(sf.encode_jnp(jnp.asarray(x, jnp.float32), fmt))
    want = sf.encode(np.asarray(x, np.float32).astype(np.float64), fmt)
    np.testing.assert_array_equal(got, want)


def test_decode_jnp_matches_numpy():
    import jax.numpy as jnp
    fmt = FPFormat(5, 3)
    codes = canonical_codes(fmt)
    got = np.asarray(sf.decode_jnp(jnp.asarray(codes, jnp.int32), fmt))
    want = sf.decode(codes, fmt).astype(np.float32)
    np.testing.assert_array_equal(np.isnan(got), np.isnan(want))
    m = ~np.isnan(want)
    np.testing.assert_array_equal(got[m], want[m])


def test_storage_format_roundtrip():
    import jax.numpy as jnp
    from repro.core.fpformat import StorageFormat
    sfmt = StorageFormat(5, 3)
    rng = np.random.default_rng(1)
    w = rng.standard_normal(256).astype(np.float32)
    codes = sf.encode_storage(jnp.asarray(w), sfmt)
    vals = np.asarray(sf.decode_storage(codes, sfmt))
    # max relative error of e5m3 RNE is 2^-4 = 6.25% (half ulp of 3-bit
    # mantissa) for values in normal range
    rel = np.abs(vals - w) / np.abs(w)
    assert rel.max() < 2 ** -4 + 1e-6
    # code 0 is exactly zero
    assert np.asarray(sf.decode_storage(jnp.zeros(1, jnp.int32), sfmt)) == 0
