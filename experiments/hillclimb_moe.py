"""Hillclimb batch: grok-1-314b train_4k (worst roofline fraction) and
olmoe-1b-7b train_4k (most collective-bound), single-pod.

Hypotheses (per EXPERIMENTS.md §Perf):
  grok  H1: per-microbatch passes dominate weight traffic; n_micro 8->2
            cuts re-reads/gathers ~4x while SP keeps activations inside
            HBM.
  grok  H2: expert capacity 1.25->1.0 trims the padded [E, C, d]
            dispatch pipeline ~20% (memory AND the TP psum bytes).
  olmoe H1: n_micro 4->1 (tiny model: activations fit) removes 3/4 of
            per-microbatch weight+dispatch traffic.
  olmoe H2: capacity 1.25->1.0, same reasoning as grok H2.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

from repro.launch.dryrun import run_cell

OUT = "experiments/perf"

# corrected-analyzer baselines
run_cell("grok-1-314b", "train_4k", "single", OUT, tag="_base")
run_cell("olmoe-1b-7b", "train_4k", "single", OUT, tag="_base")

# grok variants
run_cell("grok-1-314b", "train_4k", "single", OUT,
         overrides={"n_micro": 2}, tag="_micro2")
run_cell("grok-1-314b", "train_4k", "single", OUT,
         overrides={"n_micro": 2,
                    "cfg_replace": {"moe_capacity_factor": 1.0}},
         tag="_micro2_cap1")

# olmoe variants
run_cell("olmoe-1b-7b", "train_4k", "single", OUT,
         overrides={"n_micro": 1}, tag="_micro1")
run_cell("olmoe-1b-7b", "train_4k", "single", OUT,
         overrides={"n_micro": 1,
                    "cfg_replace": {"moe_capacity_factor": 1.0}},
         tag="_micro1_cap1")
