"""Paper Figs 6/8a/9a: MACs/second — HOBFLOPS bitslice-parallel vs
SoftFP-style word-parallel emulation vs native float.

The paper's machines are Neon/AVX2/AVX512 CPUs; here both contenders
are XLA-compiled on the host CPU backend, which preserves the paper's
*comparison* (bitslice-parallel vs integer-word emulation of the same
custom format) while the TPU numbers come from the §Roofline dry-run.
Inputs are pre-transformed (codes / bit planes), matching the paper's
"IFM and Kernel data pre-transformed to HOBFLOPS" methodology.

Three bitslice variants are measured per format to track the perf
trajectory (recorded in BENCH_macs.json by ``benchmarks/run.py``):

* ``seed``          — one MAC netlist per channel step (c_unroll=1),
                      the repo's original hot path (the gate
                      interpreter backend).
* ``chain{K}``      — the fused K-step MAC chain netlist advancing K
                      channels per step (fewer gates/MAC + fewer scan
                      steps; DESIGN.md §3).
* ``pallas_fused``  — the fused compiler backend (DESIGN.md §12): the
                      whole chain lowered to one register-file Pallas
                      kernel with the fusion-shaped bus assembly.

Every format row carries the full column set (seed / chain / fused /
speedups) plus per-format ``vs_native_f32`` / ``vs_softfp16`` ratios
so regressions read at a glance.  ``python -m benchmarks.macs --smoke``
is the CI backend-parity gate: both backends run one small workload
and the process fails on any bit mismatch.
"""
from __future__ import annotations

import functools
import time

import jax
import numpy as np

from repro.core import softfloat as sf
from repro.core.fpformat import HOBFLOPS_FORMATS, RNE, RTZ, FPFormat
from repro.core.pallas_backend import fused_chain_k, fused_mac_pallas
from repro.kernels.bitslice_mac.ops import _bitslice_mac_jnp, encode_inputs

# Workload: P output pixels x C channels x M kernels (paper Fig. 5).
P_, C_, M_ = 16, 32, 512
CHAIN_K = 4


def _time(fn, *args, iters: int = 3, reps: int = 5):
    """Best-of-reps mean over iters (robust against scheduler noise)."""
    jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, (time.perf_counter() - t0) / iters)
    return best


def _workload(fmt: FPFormat, rounding: str):
    rng = np.random.default_rng(0)
    i = rng.standard_normal((P_, C_)).astype(np.float32)
    w = rng.standard_normal((C_, M_)).astype(np.float32)
    return encode_inputs(i, w, fmt, rounding, p_block=P_,
                         m_block=M_ // 32, c_block=C_)


def bench_bitslice(fmt: FPFormat, rounding: str = RNE,
                   extended: bool = False, c_unroll: int = 1):
    i_masks, w_planes = _workload(fmt, rounding)
    fn = jax.jit(lambda a, b: _bitslice_mac_jnp(
        a, b, fmt=fmt, extended=extended, rounding=rounding,
        c_unroll=c_unroll))
    dt = _time(fn, i_masks, w_planes)
    return (P_ * C_ * M_) / dt, dt


def bench_fused(fmt: FPFormat, rounding: str = RNE,
                extended: bool = False, c_unroll: int = CHAIN_K):
    """The pallas_fused backend on the same workload; c_unroll is
    resolved through the backend's own chain-depth policy."""
    i_masks, w_planes = _workload(fmt, rounding)
    fn = jax.jit(functools.partial(
        fused_mac_pallas, fmt=fmt, extended=extended, rounding=rounding,
        p_block=P_, m_block=M_ // 32, c_block=C_, c_unroll=c_unroll,
        interpret=True))
    dt = _time(fn, i_masks, w_planes)
    return (P_ * C_ * M_) / dt, dt


def bench_softfp(fmt: FPFormat, rounding: str = RNE,
                 extended: bool = False):
    """Word-parallel integer-op FP emulation (the SoftFP analogue) over
    the same MAC count."""
    import jax.numpy as jnp

    rng = np.random.default_rng(0)
    fmt_out = fmt.mult_out(extended)
    ic = sf.encode(rng.standard_normal((P_, C_)), fmt)
    wc = sf.encode(rng.standard_normal((C_, M_)), fmt)
    icj = jnp.asarray(ic, jnp.int32)
    wcj = jnp.asarray(wc, jnp.int32)

    def mac_all(i_codes, w_codes):
        acc0 = jnp.zeros((P_, M_), jnp.int32)

        def step(acc, cw):
            col, wrow = cw
            x = jnp.broadcast_to(col[:, None], (P_, M_))
            y = jnp.broadcast_to(wrow[None, :], (P_, M_))
            return sf.fp_mac(x, y, acc, fmt, fmt_out, rounding, jnp), None

        acc, _ = jax.lax.scan(step, acc0,
                              (jnp.moveaxis(i_codes, 1, 0), w_codes))
        return acc

    fn = jax.jit(mac_all)
    dt = _time(fn, icj, wcj)
    return (P_ * C_ * M_) / dt, dt


def bench_native_f32():
    import jax.numpy as jnp

    rng = np.random.default_rng(0)
    i = jnp.asarray(rng.standard_normal((P_, C_)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((C_, M_)), jnp.float32)
    fn = jax.jit(lambda a, b: a @ b)
    dt = _time(fn, i, w)
    return (P_ * C_ * M_) / dt, dt


FORMATS_FULL = ["hobflops8", "hobflops9", "hobflops10", "hobflops11",
                "hobflops12", "hobflops14", "hobflops16"]


def _bench_format(name: str, fmt: FPFormat, rounding: str,
                  extended: bool, f32_rate: float, sf_rate: float,
                  rows: list) -> dict:
    """The full column set for one (format, rounding, extended) row —
    every benchmarked format gets the same columns (the seed report
    left extended rows with chain-only numbers)."""
    label = name + ("e" if extended else "")
    seed_rate, seed_dt = bench_bitslice(fmt, rounding, extended,
                                        c_unroll=1)
    chain_rate, chain_dt = bench_bitslice(fmt, rounding, extended,
                                          c_unroll=CHAIN_K)
    fused_k = fused_chain_k(fmt, extended, CHAIN_K)
    fused_rate, fused_dt = bench_fused(fmt, rounding, extended)
    rows.append(f"hobflops_bitslice_seed,{label},{rounding},"
                f"{seed_rate:.3e},{seed_dt*1e6:.1f}")
    rows.append(f"hobflops_bitslice_chain{CHAIN_K},{label},"
                f"{rounding},{chain_rate:.3e},{chain_dt*1e6:.1f}")
    rows.append(f"hobflops_pallas_fused,{label},{rounding},"
                f"{fused_rate:.3e},{fused_dt*1e6:.1f}")
    best = max(seed_rate, chain_rate, fused_rate)
    return {
        "seed_macs_per_s": seed_rate,
        f"chain{CHAIN_K}_macs_per_s": chain_rate,
        "speedup_vs_seed": chain_rate / seed_rate,
        "pallas_fused_macs_per_s": fused_rate,
        "fused_chain_k": fused_k,
        "fused_speedup_vs_interpreter": fused_rate / seed_rate,
        "vs_native_f32": best / f32_rate,
        "vs_softfp16": best / sf_rate,
    }


def run(quick: bool = False):
    formats = ["hobflops8", "hobflops9", "hobflops16"] if quick \
        else FORMATS_FULL
    rows = ["impl,format,rounding,macs_per_s,us_per_call"]
    results = {"workload": {"P": P_, "C": C_, "M": M_,
                            "macs": P_ * C_ * M_},
               "chain_k": CHAIN_K, "formats": {}}
    f32_rate, f32_dt = bench_native_f32()
    rows.append(f"native_f32,f32,-,{f32_rate:.3e},{f32_dt*1e6:.1f}")
    results["native_f32_macs_per_s"] = f32_rate
    sf_rate, sf_dt = bench_softfp(HOBFLOPS_FORMATS["hobflops16"])
    rows.append(f"softfp_word,hobflops16,rne,{sf_rate:.3e},"
                f"{sf_dt*1e6:.1f}")
    results["softfp16_macs_per_s"] = sf_rate
    for name in formats:
        fmt = HOBFLOPS_FORMATS[name]
        per_fmt = results["formats"].setdefault(name, {})
        for rounding in ((RNE,) if quick else (RNE, RTZ)):
            per_fmt[rounding] = _bench_format(name, fmt, rounding, False,
                                              f32_rate, sf_rate, rows)
    for name in (["hobflops9"] if quick else ["hobflops8", "hobflops9",
                                              "hobflops16"]):
        results["formats"].setdefault(name + "e", {})["rne"] = \
            _bench_format(name, HOBFLOPS_FORMATS[name], RNE, True,
                          f32_rate, sf_rate, rows)
    return "\n".join(rows), results


# ---------------------------------------------------------------------------
# CI backend-parity smoke
# ---------------------------------------------------------------------------
def smoke() -> bool:
    """Both backends on one small workload, compared bit-for-bit on
    the raw OFM planes — the CI ``backend-parity`` gate.  Covers the
    plain-stack (hobflops8) and one-hot (hobflops16) assembly paths.
    Returns True on exact agreement."""
    ok = True
    for name in ("hobflops8", "hobflops16"):
        fmt = HOBFLOPS_FORMATS[name]
        i_masks, w_planes = _workload(fmt, RNE)
        ku = fused_chain_k(fmt, False, CHAIN_K)
        ref = np.asarray(jax.jit(functools.partial(
            _bitslice_mac_jnp, fmt=fmt, extended=False, rounding=RNE,
            c_unroll=ku))(i_masks, w_planes))
        got = np.asarray(jax.jit(functools.partial(
            fused_mac_pallas, fmt=fmt, extended=False, rounding=RNE,
            p_block=P_, m_block=M_ // 32, c_block=C_, c_unroll=ku,
            interpret=True))(i_masks, w_planes))
        same = np.array_equal(ref, got)
        ok &= same
        print(f"smoke {name}: jnp vs pallas_fused "
              f"{'MATCH' if same else 'MISMATCH'} "
              f"(planes {ref.shape}, chain_k={ku})")
    return ok


if __name__ == "__main__":
    import sys

    if "--smoke" in sys.argv:
        sys.exit(0 if smoke() else 1)
    text, _ = run("--quick" in sys.argv)
    print(text)
