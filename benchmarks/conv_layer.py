"""Paper §3.4/§4: the CNN convolution layer experiment.

MobileNets' 14x14x512 feature-map stage: the pointwise Conv/s1
1x1x512x512 that dominates its MACs (and a 3x3 general conv at reduced
width), in HOBFLOPS9 bitslice arithmetic with in-format ReLU, vs the
same layer in f32 — reporting MACs/s and the quantization error.
Dimensions are scaled by --scale for CPU wall-clock sanity; the MACs/s
figure is what the paper's Figs 6/8a/9a report.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fpformat import HOBFLOPS_FORMATS
from repro.kernels.conv2d_bitslice.ops import hobflops_conv2d
from repro.kernels.conv2d_bitslice.ref import conv2d_f32


def bench_conv(fmt_name: str = "hobflops9", hw: int = 14, cin: int = 64,
               cout: int = 64, kh: int = 1, relu: bool = True):
    fmt = HOBFLOPS_FORMATS[fmt_name]
    rng = np.random.default_rng(0)
    img = rng.standard_normal((1, hw, hw, cin)).astype(np.float32)
    ker = (rng.standard_normal((kh, kh, cin, cout)) * 0.2).astype(
        np.float32)

    fn = jax.jit(lambda a, b: hobflops_conv2d(
        a, b, fmt=fmt, relu=relu, backend="jnp"))
    out = fn(img, ker)
    jax.block_until_ready(out)
    t0 = time.time()
    out = fn(img, ker)
    jax.block_until_ready(out)
    dt = time.time() - t0

    f32 = np.asarray(conv2d_f32(img, ker))
    if relu:
        f32 = np.maximum(f32, 0)
    err = np.abs(np.asarray(out) - f32).max() / (np.abs(f32).max() + 1e-9)
    macs = img.shape[0] * hw * hw * kh * kh * cin * cout
    return {"format": fmt_name, "kh": kh, "macs_per_s": macs / dt,
            "us_per_call": dt * 1e6, "rel_err_vs_f32": float(err)}


def run(quick: bool = False):
    rows = ["name,format,macs_per_s,us_per_call,rel_err"]
    cases = [("pointwise_14x14", "hobflops9", 1, 64, 64)]
    if not quick:
        cases += [("pointwise_14x14", "hobflops8", 1, 64, 64),
                  ("conv3x3_14x14", "hobflops9", 3, 32, 32)]
    results = {}
    for name, fmt, kh, cin, cout in cases:
        r = bench_conv(fmt, 14, cin, cout, kh)
        rows.append(f"{name},{fmt},{r['macs_per_s']:.3e},"
                    f"{r['us_per_call']:.1f},{r['rel_err_vs_f32']:.4f}")
        results[(name, fmt)] = r
    return "\n".join(rows), results


if __name__ == "__main__":
    print(run()[0])
