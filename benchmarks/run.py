"""Benchmark runner: one section per paper table/figure.

  gates      — MAC gate counts per cell library (paper Figs 7, 8b, 9b)
  macs       — MACs/s bitslice vs SoftFP word emulation (Figs 6, 8a, 9a)
  conv       — CNN convolution layer in HOBFLOPS (paper §3.4/§4)
  network    — multi-layer stack: bitslice-resident pipeline vs
               per-layer decode/re-encode (paper §3.4, DESIGN.md §8)
  serve      — lane-batched serving engine: wave throughput vs batch
               bucket vs the one-request-at-a-time loop (DESIGN.md §10)
  roofline   — assembled dry-run roofline table (§Roofline), if
               experiments/dryrun has been populated

Prints ``name,us_per_call,derived`` CSV blocks per section.  The
``gates`` and ``macs`` sections additionally write machine-readable
``BENCH_gates.json`` / ``BENCH_macs.json`` (gates/MAC per format +
library; MACs/s per format) so successive PRs have a perf trajectory:

    python benchmarks/run.py --quick --only macs,gates
"""
from __future__ import annotations

import argparse
import json
import os
import time

_JSON_SECTIONS = ("gates", "macs", "network", "serve")


def _write_json(out_dir: str, section: str, results) -> str:
    path = os.path.join(out_dir, f"BENCH_{section}.json")
    with open(path, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small format subset (CI-speed)")
    ap.add_argument("--only", default=None,
                    help="comma list: gates,macs,conv,network,serve,roofline")
    ap.add_argument("--out-dir", default=".",
                    help="directory for BENCH_<section>.json files")
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else None
    sections = [s for s in ("gates", "macs", "conv", "network", "serve",
                            "roofline")
                if only is None or s in only]

    for sec in sections:
        t0 = time.time()
        print(f"== {sec} ==", flush=True)
        try:
            if sec == "gates":
                from benchmarks import gates
                text, results = gates.run(quick=args.quick)
            elif sec == "macs":
                from benchmarks import macs
                text, results = macs.run(quick=args.quick)
            elif sec == "conv":
                from benchmarks import conv_layer
                text, results = conv_layer.run(quick=args.quick)
            elif sec == "network":
                from benchmarks import network
                text, results = network.run(quick=args.quick)
            elif sec == "serve":
                from benchmarks import serve
                text, results = serve.run(quick=args.quick)
            else:
                from benchmarks import roofline
                text, results = roofline.run(quick=args.quick)
            print(text, flush=True)
            if sec in _JSON_SECTIONS:
                path = _write_json(args.out_dir, sec, results)
                print(f"wrote {path}", flush=True)
        except Exception as e:  # keep the harness going
            print(f"SECTION-ERROR {sec}: {type(e).__name__}: {e}",
                  flush=True)
        print(f"== {sec} done in {time.time()-t0:.1f}s ==\n", flush=True)


if __name__ == "__main__":
    main()
