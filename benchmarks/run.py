"""Benchmark runner: one section per paper table/figure.

  gates      — MAC gate counts per cell library (paper Figs 7, 8b, 9b)
  macs       — MACs/s bitslice vs SoftFP word emulation (Figs 6, 8a, 9a)
  conv       — CNN convolution layer in HOBFLOPS (paper §3.4/§4)
  roofline   — assembled dry-run roofline table (§Roofline), if
               experiments/dryrun has been populated

Prints ``name,us_per_call,derived`` CSV blocks per section.
"""
from __future__ import annotations

import argparse
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small format subset (CI-speed)")
    ap.add_argument("--only", default=None,
                    help="comma list: gates,macs,conv,roofline")
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else None
    sections = [s for s in ("gates", "macs", "conv", "roofline")
                if only is None or s in only]

    for sec in sections:
        t0 = time.time()
        print(f"== {sec} ==", flush=True)
        try:
            if sec == "gates":
                from benchmarks import gates
                text, _ = gates.run(quick=args.quick)
            elif sec == "macs":
                from benchmarks import macs
                text, _ = macs.run(quick=args.quick)
            elif sec == "conv":
                from benchmarks import conv_layer
                text, _ = conv_layer.run(quick=args.quick)
            else:
                from benchmarks import roofline
                text, _ = roofline.run(quick=args.quick)
            print(text, flush=True)
        except Exception as e:  # keep the harness going
            print(f"SECTION-ERROR {sec}: {type(e).__name__}: {e}",
                  flush=True)
        print(f"== {sec} done in {time.time()-t0:.1f}s ==\n", flush=True)


if __name__ == "__main__":
    main()
