"""Multi-layer pipeline benchmark: bitslice-resident vs per-layer
decode/re-encode (paper §3.4's "data stays in HOBFLOPS format between
layers", DESIGN.md §8).

Workload: a MobileNets-style pointwise stack (the paper's Fig. 5 layer,
depth-chained; channel width scaled for CPU wall-clock like
``conv_layer.py``).  Three contenders over identical arithmetic:

* ``resident``     — :class:`HobflopsNetwork`: one activation encode,
                     one decode, bitwise format casts + plane-domain
                     im2col at every interior boundary; weights
                     pre-encoded once.
* ``roundtrip``    — the pre-PR per-layer path: chained
                     ``hobflops_conv2d`` calls with f32 kernels, paying
                     activation decode/re-encode *and* weight
                     re-encoding at every layer.  The headline
                     ``speedup_vs_roundtrip`` is against this (the
                     trajectory baseline: what callers paid before the
                     resident pipeline existed).
* ``roundtrip_preencoded`` — per-layer calls with ``ConvWeights``:
                     isolates the activation-residency saving alone
                     (``speedup_vs_preencoded``) from the weight
                     pre-encoding saving, which per-layer callers can
                     also get via ``hobflops_conv2d(ConvWeights)``.

All three produce bit-identical outputs (tests assert it).  Timing is
best-of-reps, interleaved rep-by-rep, to reject scheduler noise on
shared CPUs.

A second workload, ``residual_pool``, exercises the graph runner
(DESIGN.md §9): a residual block with in-domain max/avg pooling and a
strided downsample, measured resident vs the per-layer f32-boundary
oracle path (``NetworkGraph.run_roundtrip``) and emitted as the
``residual_pool`` section of ``BENCH_network.json``.  Note the graph
workload is only ~4 convs deep: the entry ``pack_planes`` cost the
resident path pays once (the per-layer path never packs planes — its
convs go straight from f32 to broadcast masks) is amortized over far
fewer layers than in the 8-deep stack, so expect ~parity here on CPU
versus the clear resident win on the deep stack.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core.fpformat import HOBFLOPS_FORMATS
from repro.kernels.conv2d_bitslice.network import (ConvLayerSpec,
                                                   HobflopsNetwork,
                                                   NetworkGraph)
from repro.kernels.conv2d_bitslice.ops import hobflops_conv2d

# Workload: depth x (1x1, C->C) convs on a HW x HW feature map.
HW_, C_, DEPTH_, KH_ = 14, 8, 8, 1
# residual_pool workload: graph topology feature-map side / channels.
G_HW_, G_C_ = 12, 8


def _time_all(fns, iters: int = 20, reps: int = 8):
    """Best-of-reps for several contenders, *interleaved* rep-by-rep so
    scheduler noise on shared CPUs hits every contender equally."""
    for fn in fns:
        jax.block_until_ready(fn())
    best = [float("inf")] * len(fns)
    for _ in range(reps):
        for i, fn in enumerate(fns):
            t0 = time.perf_counter()
            for _ in range(iters):
                out = fn()
            jax.block_until_ready(out)
            best[i] = min(best[i], (time.perf_counter() - t0) / iters)
    return best


def build_stack(fmt_name: str, hw: int = HW_, c: int = C_,
                depth: int = DEPTH_, kh: int = KH_, seed: int = 0):
    """Returns (images, f32 kernel list, HobflopsNetwork)."""
    fmt = HOBFLOPS_FORMATS[fmt_name]
    rng = np.random.default_rng(seed)
    img = rng.standard_normal((1, hw, hw, c)).astype(np.float32)
    kernels = [(rng.standard_normal((kh, kh, c, c)) * 0.3)
               .astype(np.float32) for _ in range(depth)]
    net = HobflopsNetwork([ConvLayerSpec(k, fmt, relu=True)
                           for k in kernels])
    return img, kernels, net


def bench_network(fmt_name: str, hw: int = HW_, c: int = C_,
                  depth: int = DEPTH_, kh: int = KH_,
                  iters: int = 20, reps: int = 8, stack=None) -> dict:
    img, kernels, net = stack or build_stack(fmt_name, hw, c, depth, kh)
    fmt = HOBFLOPS_FORMATS[fmt_name]
    macs = net.macs(img.shape)

    def roundtrip():
        x = img
        for k in kernels:
            x = hobflops_conv2d(x, k, fmt=fmt, relu=True, backend="jnp")
        return x

    def roundtrip_preencoded():
        x = img
        for w in net.weights:
            x = hobflops_conv2d(x, w, fmt=fmt, relu=True, backend="jnp")
        return x

    dt_res, dt_rt, dt_pre = _time_all(
        [lambda: net(img), roundtrip, roundtrip_preencoded], iters, reps)
    return {
        "format": fmt_name, "depth": depth, "hw": hw, "c": c, "kh": kh,
        "macs": macs,
        "resident_macs_per_s": macs / dt_res,
        "roundtrip_macs_per_s": macs / dt_rt,
        "roundtrip_preencoded_macs_per_s": macs / dt_pre,
        "resident_us_per_call": dt_res * 1e6,
        "roundtrip_us_per_call": dt_rt * 1e6,
        "roundtrip_preencoded_us_per_call": dt_pre * 1e6,
        "speedup_vs_roundtrip": dt_rt / dt_res,
        "speedup_vs_preencoded": dt_pre / dt_res,
    }


def build_residual_pool(fmt_name: str, hw: int = G_HW_, c: int = G_C_,
                        seed: int = 0):
    """The graph-runner workload (DESIGN.md §9): 3x3 conv -> maxpool ->
    residual pointwise block -> strided 3x3 downsample -> 2x2 avgpool
    head.  Returns (images, NetworkGraph)."""
    fmt = HOBFLOPS_FORMATS[fmt_name]
    rng = np.random.default_rng(seed)
    img = rng.standard_normal((1, hw, hw, c)).astype(np.float32)

    def k(*shape, s=0.3):
        return (rng.standard_normal(shape) * s).astype(np.float32)

    g = NetworkGraph(fmt)
    c1 = g.conv("c1", g.input_name, k(3, 3, c, c), relu=True)
    p1 = g.maxpool2d("p1", c1, window=2)
    c2 = g.conv("c2", p1, k(1, 1, c, c), relu=True)
    c3 = g.conv("c3", c2, k(1, 1, c, c))
    res = g.relu("r", g.add("res", c3, p1))
    d = g.conv("d", res, k(3, 3, c, c), stride=2)
    g.output(g.avgpool2d("head", d, window=2))
    return img, g


def bench_residual_pool(fmt_name: str, hw: int = G_HW_, c: int = G_C_,
                        iters: int = 20, reps: int = 8,
                        stack=None) -> dict:
    """Resident vs per-layer-oracle MACs/s for the residual_pool graph
    (in-domain pooling + residual adds vs f32 boundaries + word-parallel
    softfloat pooling at every node)."""
    img, g = stack or build_residual_pool(fmt_name, hw, c)
    macs = g.macs(img.shape)
    dt_res, dt_rt = _time_all([lambda: g.run(img),
                               lambda: g.run_roundtrip(img)], iters, reps)
    return {
        "format": fmt_name, "hw": hw, "c": c, "macs": macs,
        "resident_macs_per_s": macs / dt_res,
        "roundtrip_macs_per_s": macs / dt_rt,
        "resident_us_per_call": dt_res * 1e6,
        "roundtrip_us_per_call": dt_rt * 1e6,
        "speedup_vs_roundtrip": dt_rt / dt_res,
    }


def smoke(fmt_name: str = "hobflops8", hw: int = 6, c: int = 8,
          depth: int = 3) -> dict:
    """Tiny run for the tier-1 smoke test: builds the stack, checks the
    resident path is bit-exact vs the per-layer roundtrip, and returns
    a result row (1 iter, 1 rep, stack reused)."""
    stack = build_stack(fmt_name, hw, c, depth)
    img, _, net = stack
    res = np.asarray(net(img))
    rt = np.asarray(net.run_roundtrip(img))
    assert res.shape == net.out_shape(img.shape), (res.shape, img.shape)
    assert (res == rt).all(), "resident != per-layer roundtrip"
    # the graph workload too: residual + pools, still bit-exact
    gimg, g = build_residual_pool(fmt_name, hw=8, c=4)
    gres = np.asarray(g.run(gimg))
    assert (gres == np.asarray(g.run_roundtrip(gimg))).all(), \
        "graph resident != per-layer oracle"
    row = bench_network(fmt_name, hw, c, depth, iters=1, reps=1,
                        stack=stack)
    row["residual_pool"] = bench_residual_pool(fmt_name, hw=8, c=4,
                                               iters=1, reps=1,
                                               stack=(gimg, g))
    return row


def run(quick: bool = False):
    formats = ["hobflops8", "hobflops9"] if quick else \
        ["hobflops8", "hobflops9", "hobflops10", "hobflops16"]
    rows = ["impl,format,macs_per_s,us_per_call,speedup_vs_roundtrip"]
    results = {"workload": {"hw": HW_, "c": C_, "depth": DEPTH_,
                            "kh": KH_},
               "residual_pool_workload": {"hw": G_HW_, "c": G_C_},
               "formats": {}, "residual_pool": {}}
    for name in formats:
        r = bench_network(name)
        rows.append(f"network_resident,{name},"
                    f"{r['resident_macs_per_s']:.3e},"
                    f"{r['resident_us_per_call']:.1f},"
                    f"{r['speedup_vs_roundtrip']:.2f}")
        rows.append(f"network_roundtrip,{name},"
                    f"{r['roundtrip_macs_per_s']:.3e},"
                    f"{r['roundtrip_us_per_call']:.1f},1.00")
        rows.append(f"network_roundtrip_preencoded,{name},"
                    f"{r['roundtrip_preencoded_macs_per_s']:.3e},"
                    f"{r['roundtrip_preencoded_us_per_call']:.1f},"
                    f"{r['roundtrip_preencoded_macs_per_s'] / r['roundtrip_macs_per_s']:.2f}")
        results["formats"][name] = r
        gr = bench_residual_pool(name)
        rows.append(f"residual_pool_resident,{name},"
                    f"{gr['resident_macs_per_s']:.3e},"
                    f"{gr['resident_us_per_call']:.1f},"
                    f"{gr['speedup_vs_roundtrip']:.2f}")
        rows.append(f"residual_pool_roundtrip,{name},"
                    f"{gr['roundtrip_macs_per_s']:.3e},"
                    f"{gr['roundtrip_us_per_call']:.1f},1.00")
        results["residual_pool"][name] = gr
    return "\n".join(rows), results


if __name__ == "__main__":
    text, _ = run()
    print(text)
