"""Multi-layer pipeline benchmark: bitslice-resident vs per-layer
decode/re-encode (paper §3.4's "data stays in HOBFLOPS format between
layers", DESIGN.md §8).

Workload: a MobileNets-style pointwise stack (the paper's Fig. 5 layer,
depth-chained; channel width scaled for CPU wall-clock like
``conv_layer.py``).  Three contenders over identical arithmetic:

* ``resident``     — :class:`HobflopsNetwork`: one activation encode,
                     one decode, bitwise format casts + plane-domain
                     im2col at every interior boundary; weights
                     pre-encoded once.
* ``roundtrip``    — the pre-PR per-layer path: chained
                     ``hobflops_conv2d`` calls with f32 kernels, paying
                     activation decode/re-encode *and* weight
                     re-encoding at every layer.  The headline
                     ``speedup_vs_roundtrip`` is against this (the
                     trajectory baseline: what callers paid before the
                     resident pipeline existed).
* ``roundtrip_preencoded`` — per-layer calls with ``ConvWeights``:
                     isolates the activation-residency saving alone
                     (``speedup_vs_preencoded``) from the weight
                     pre-encoding saving, which per-layer callers can
                     also get via ``hobflops_conv2d(ConvWeights)``.

All three produce bit-identical outputs (tests assert it).  Timing is
best-of-reps, interleaved rep-by-rep, to reject scheduler noise on
shared CPUs.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core.fpformat import HOBFLOPS_FORMATS
from repro.kernels.conv2d_bitslice.network import (ConvLayerSpec,
                                                   HobflopsNetwork)
from repro.kernels.conv2d_bitslice.ops import hobflops_conv2d

# Workload: depth x (1x1, C->C) convs on a HW x HW feature map.
HW_, C_, DEPTH_, KH_ = 14, 8, 8, 1


def _time_all(fns, iters: int = 20, reps: int = 8):
    """Best-of-reps for several contenders, *interleaved* rep-by-rep so
    scheduler noise on shared CPUs hits every contender equally."""
    for fn in fns:
        jax.block_until_ready(fn())
    best = [float("inf")] * len(fns)
    for _ in range(reps):
        for i, fn in enumerate(fns):
            t0 = time.perf_counter()
            for _ in range(iters):
                out = fn()
            jax.block_until_ready(out)
            best[i] = min(best[i], (time.perf_counter() - t0) / iters)
    return best


def build_stack(fmt_name: str, hw: int = HW_, c: int = C_,
                depth: int = DEPTH_, kh: int = KH_, seed: int = 0):
    """Returns (images, f32 kernel list, HobflopsNetwork)."""
    fmt = HOBFLOPS_FORMATS[fmt_name]
    rng = np.random.default_rng(seed)
    img = rng.standard_normal((1, hw, hw, c)).astype(np.float32)
    kernels = [(rng.standard_normal((kh, kh, c, c)) * 0.3)
               .astype(np.float32) for _ in range(depth)]
    net = HobflopsNetwork([ConvLayerSpec(k, fmt, relu=True)
                           for k in kernels])
    return img, kernels, net


def bench_network(fmt_name: str, hw: int = HW_, c: int = C_,
                  depth: int = DEPTH_, kh: int = KH_,
                  iters: int = 20, reps: int = 8, stack=None) -> dict:
    img, kernels, net = stack or build_stack(fmt_name, hw, c, depth, kh)
    fmt = HOBFLOPS_FORMATS[fmt_name]
    macs = net.macs(img.shape)

    def roundtrip():
        x = img
        for k in kernels:
            x = hobflops_conv2d(x, k, fmt=fmt, relu=True, backend="jnp")
        return x

    def roundtrip_preencoded():
        x = img
        for w in net.weights:
            x = hobflops_conv2d(x, w, fmt=fmt, relu=True, backend="jnp")
        return x

    dt_res, dt_rt, dt_pre = _time_all(
        [lambda: net(img), roundtrip, roundtrip_preencoded], iters, reps)
    return {
        "format": fmt_name, "depth": depth, "hw": hw, "c": c, "kh": kh,
        "macs": macs,
        "resident_macs_per_s": macs / dt_res,
        "roundtrip_macs_per_s": macs / dt_rt,
        "roundtrip_preencoded_macs_per_s": macs / dt_pre,
        "resident_us_per_call": dt_res * 1e6,
        "roundtrip_us_per_call": dt_rt * 1e6,
        "roundtrip_preencoded_us_per_call": dt_pre * 1e6,
        "speedup_vs_roundtrip": dt_rt / dt_res,
        "speedup_vs_preencoded": dt_pre / dt_res,
    }


def smoke(fmt_name: str = "hobflops8", hw: int = 6, c: int = 8,
          depth: int = 3) -> dict:
    """Tiny run for the tier-1 smoke test: builds the stack, checks the
    resident path is bit-exact vs the per-layer roundtrip, and returns
    a result row (1 iter, 1 rep, stack reused)."""
    stack = build_stack(fmt_name, hw, c, depth)
    img, _, net = stack
    res = np.asarray(net(img))
    rt = np.asarray(net.run_roundtrip(img))
    assert res.shape == net.out_shape(img.shape), (res.shape, img.shape)
    assert (res == rt).all(), "resident != per-layer roundtrip"
    return bench_network(fmt_name, hw, c, depth, iters=1, reps=1,
                         stack=stack)


def run(quick: bool = False):
    formats = ["hobflops8", "hobflops9"] if quick else \
        ["hobflops8", "hobflops9", "hobflops10", "hobflops16"]
    rows = ["impl,format,macs_per_s,us_per_call,speedup_vs_roundtrip"]
    results = {"workload": {"hw": HW_, "c": C_, "depth": DEPTH_,
                            "kh": KH_},
               "formats": {}}
    for name in formats:
        r = bench_network(name)
        rows.append(f"network_resident,{name},"
                    f"{r['resident_macs_per_s']:.3e},"
                    f"{r['resident_us_per_call']:.1f},"
                    f"{r['speedup_vs_roundtrip']:.2f}")
        rows.append(f"network_roundtrip,{name},"
                    f"{r['roundtrip_macs_per_s']:.3e},"
                    f"{r['roundtrip_us_per_call']:.1f},1.00")
        rows.append(f"network_roundtrip_preencoded,{name},"
                    f"{r['roundtrip_preencoded_macs_per_s']:.3e},"
                    f"{r['roundtrip_preencoded_us_per_call']:.1f},"
                    f"{r['roundtrip_preencoded_macs_per_s'] / r['roundtrip_macs_per_s']:.2f}")
        results["formats"][name] = r
    return "\n".join(rows), results


if __name__ == "__main__":
    text, _ = run()
    print(text)
