"""§Roofline: assemble the per-cell roofline table from the dry-run
JSONs (experiments/dryrun/*.json) produced by repro.launch.dryrun.

Adds the MODEL_FLOPS = 6·N·D analytical term (N = active params for
MoE) and the usefulness ratio MODEL_FLOPS / HLO_FLOPs that catches
remat/replication waste.  Numbers are per chip (the compiled module is
post-SPMD); MODEL_FLOPS is divided by the device count accordingly.
"""
from __future__ import annotations

import json
import math
import pathlib

import jax

from repro.configs import get_config
from repro.models import model_schema
from repro.models.config import SHAPES
from repro.models.schema import P as SchemaP

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9


def active_param_count(cfg) -> int:
    """Parameters touched per token: experts scaled by top_k/E."""
    schema = model_schema(cfg)
    total = 0

    def walk(tree, in_moe):
        nonlocal total
        if isinstance(tree, dict):
            for k, v in tree.items():
                walk(v, in_moe or k == "moe")
        elif isinstance(tree, SchemaP):
            n = math.prod(tree.shape)
            if in_moe and cfg.moe_experts:
                n = n * cfg.moe_top_k // cfg.moe_experts
            total += n
    walk(schema, False)
    return total


def model_flops(cfg, shape, kind: str) -> float:
    n = active_param_count(cfg)
    if kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence per step
    return 2.0 * n * shape.global_batch


def load_cells(dryrun_dir="experiments/dryrun"):
    cells = []
    for p in sorted(pathlib.Path(dryrun_dir).glob("*.json")):
        rec = json.loads(p.read_text())
        cells.append(rec)
    return cells


def table(dryrun_dir="experiments/dryrun", mesh: str | None = "single"):
    rows = []
    header = ("| arch | shape | mesh | compute_s | memory_s | coll_s | "
              "dominant | model_flops/hlo | fits_hbm | note |")
    rows.append(header)
    rows.append("|" + "---|" * 10)
    for rec in load_cells(dryrun_dir):
        if mesh and rec.get("mesh") != mesh:
            continue
        if rec.get("status") == "skip":
            rows.append(f"| {rec['arch']} | {rec['shape']} | {rec['mesh']}"
                        f" | — | — | — | skip | — | — | "
                        f"{rec['reason'][:60]} |")
            continue
        if rec.get("status") != "ok":
            rows.append(f"| {rec['arch']} | {rec['shape']} | {rec['mesh']}"
                        f" | — | — | — | ERROR | — | — | "
                        f"{rec.get('error', '')[:60]} |")
            continue
        cfg = get_config(rec["arch"])
        shape = SHAPES[rec["shape"]]
        mf = model_flops(cfg, shape, rec["kind"]) / rec["n_devices"]
        hlo = rec["hlo_cost"]["flops"]
        r = rec["roofline"]
        temp = rec["memory_analysis"].get("temp_size_in_bytes", 0)
        args = rec["memory_analysis"].get("argument_size_in_bytes", 0)
        fits = (temp + args) <= 16 * 2 ** 30
        rows.append(
            f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} "
            f"| {r['compute_s']:.4f} | {r['memory_s']:.4f} "
            f"| {r['collective_s']:.4f} | {r['dominant']} "
            f"| {mf / max(hlo, 1):.3f} | {'Y' if fits else 'N'} "
            f"| temp={temp / 2**30:.1f}GiB |")
    return "\n".join(rows)


def run(quick: bool = False):
    t = table()
    cells = [c for c in load_cells() if c.get("status") == "ok"]
    n_ok = len(cells)
    n_skip = sum(1 for c in load_cells() if c.get("status") == "skip")
    summary = f"roofline_cells_ok,{n_ok},skip={n_skip}"
    return t + "\n" + summary, {"ok": n_ok, "skip": n_skip}


if __name__ == "__main__":
    print(run()[0])
