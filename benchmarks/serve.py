"""Lane-batched serving benchmark: wave throughput vs batch size vs the
one-request-at-a-time loop (DESIGN.md §10).

The carrier's pixel-row axis is the batch axis, so a wave of N images
runs through one compiled resident call whose fixed costs (dispatch,
one encode/decode, per-netlist op issue) are batch-invariant until the
plane arrays saturate the machine — serving cost per image falls with
occupancy.  This benchmark measures exactly that: for each batch
bucket B, a :class:`ConvServeEngine` serves B queued single-image
requests as one wave, against the baseline of B sequential
``graph.run`` calls on one image each (what callers paid before the
engine existed).  The engine path is timed end-to-end including its
host-side pack/unpack — the honest serving cost.

Emits ``BENCH_serve.json``: per format, the single-request baseline
and per-bucket wave timings with images/s, MACs/s, and the speedup vs
the one-at-a-time loop.  The acceptance trajectory expects throughput
to increase with bucket size, ≥2x at the largest bucket on hobflops8.

Autotuned launch blocks come through the ``tuned_conv_blocks`` disk
cache (``serve_conv/cache.py``), so repeat benchmark runs skip the
sweep; override the cache path with ``HOBFLOPS_TUNE_CACHE``.
"""
from __future__ import annotations

import numpy as np

from benchmarks.network import _time_all
from repro.core.fpformat import HOBFLOPS_FORMATS
from repro.kernels.conv2d_bitslice.network import NetworkGraph
from repro.serve_conv import (ConvRequest, ConvServeEngine, RunnerCache,
                              tuned_conv_blocks)

# Serving workload: 3x3 conv -> pointwise conv -> 2x2 maxpool on a
# HW x HW x C image.  Small on purpose: per-image marginal cost is the
# fused gate-eval compute (scales with B*H*W rows), while the per-wave
# fixed cost (call dispatch, per-op launch, encode/decode) is
# batch-invariant — the request-batching regime the lane packer
# targets, analogous to small-image high-QPS traffic on a wide
# machine.  Larger images shift the balance toward marginal compute
# and the batching win shrinks toward 1x (see BENCH_network.json for
# the compute-bound trajectory).
HW_, C_ = 4, 4
BUCKETS = (1, 2, 4, 8, 16)


def build_serve_graph(fmt_name: str, hw: int = HW_, c: int = C_,
                      seed: int = 0, blocks: dict | None = None):
    """Returns (single [1,hw,hw,c] image, request rng, NetworkGraph).
    ``blocks`` pins tuned launch parameters on both conv nodes (the
    runners thread them into the kernel launch)."""
    fmt = HOBFLOPS_FORMATS[fmt_name]
    rng = np.random.default_rng(seed)
    g = NetworkGraph(fmt)
    c1 = g.conv("c1", g.input_name,
                (rng.standard_normal((3, 3, c, c)) * 0.3)
                .astype(np.float32), relu=True, blocks=blocks)
    c2 = g.conv("c2", c1,
                (rng.standard_normal((1, 1, c, c)) * 0.3)
                .astype(np.float32), relu=True, blocks=blocks)
    g.output(g.maxpool2d("head", c2, window=2))
    img = rng.standard_normal((1, hw, hw, c)).astype(np.float32)
    return img, rng, g


def bench_serve(fmt_name: str, hw: int = HW_, c: int = C_,
                buckets=BUCKETS, iters: int = 10, reps: int = 5,
                tune_path: str | None = None) -> dict:
    img, _, g0 = build_serve_graph(fmt_name, hw, c)
    blocks, _ = tuned_conv_blocks(
        img, g0._weights["c1"], fmt=HOBFLOPS_FORMATS[fmt_name],
        candidates=[{"c_unroll": 4, "m_block": m} for m in (8, 128)],
        iters=1, path=tune_path)
    # rebuild with the tuned blocks pinned on the conv nodes, so the
    # timed waves actually execute the tuned configuration
    img, rng, g = build_serve_graph(fmt_name, hw, c, blocks=blocks)
    macs = g.macs(img.shape)

    cache = RunnerCache()
    images = {b: [rng.standard_normal((hw, hw, c)).astype(np.float32)
                  for _ in range(b)] for b in buckets}
    engines = {b: ConvServeEngine(g, (hw, hw, c), max_batch=b,
                                  blocks=blocks, runner_cache=cache)
               for b in buckets}

    def serve(b):
        eng = engines[b]
        for i, im in enumerate(images[b]):
            eng.submit(ConvRequest(i, im))
        return eng.run()[-1].out

    largest = max(buckets)

    def single_loop():
        out = None
        for im in images[largest]:
            out = g.run(im[None])
        return out

    # One interleaved timing set: every bucket's wave AND the shared
    # one-request-at-a-time baseline ride the same reps, so machine
    # drift hits all contenders equally and the per-bucket throughput
    # trend is comparable (a per-bucket baseline re-measure showed 2x
    # cross-bucket drift on shared CPUs).
    fns = [lambda b=b: serve(b) for b in buckets] + [single_loop]
    times = _time_all(fns, iters, reps)
    dt_single = times[-1] / largest            # per image, one per call
    results = {}
    for b, dt_wave in zip(buckets, times):
        results[str(b)] = {
            "bucket": b,
            "wave_us": dt_wave * 1e6,
            "wave_images_per_s": b / dt_wave,
            "wave_macs_per_s": b * macs / dt_wave,
            "speedup_vs_single": b * dt_single / dt_wave,
            "occupancy": engines[b].stats()["mean_occupancy"],
        }
    return {"format": fmt_name, "hw": hw, "c": c,
            "macs_per_image": macs, "blocks": blocks,
            "single_us_per_image": dt_single * 1e6,
            "single_images_per_s": 1.0 / dt_single,
            "single_macs_per_s": macs / dt_single,
            "buckets": results}


def smoke(fmt_name: str = "hobflops8", hw: int = 6, c: int = 4) -> dict:
    """Tier-1 smoke: a tiny graph serves 5 queued requests across a
    ragged wave split and every output is bit-identical to the
    per-request ``graph.run``."""
    img, rng, g = build_serve_graph(fmt_name, hw, c)
    eng = ConvServeEngine(g, (hw, hw, c), max_batch=4)
    reqs = [ConvRequest(i, rng.standard_normal((hw, hw, c))
                        .astype(np.float32)) for i in range(5)]
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert len(done) == 5 and eng.waves == 2     # 4 + ragged 1
    for r in done:
        solo = np.asarray(g.run(r.image[None]))[0]
        assert (r.out == solo).all(), f"request {r.rid} not bit-exact"
    st = eng.stats()
    assert st["images_served"] == 5
    return st


def run(quick: bool = False):
    formats = ["hobflops8", "hobflops9"]
    buckets = BUCKETS if not quick else (1, 2, 4, 8)
    iters, reps = (4, 3) if quick else (10, 5)
    rows = ["format,bucket,wave_images_per_s,single_images_per_s,"
            "speedup_vs_single"]
    results = {"workload": {"hw": HW_, "c": C_, "buckets": list(buckets)},
               "formats": {}}
    for name in formats:
        r = bench_serve(name, buckets=buckets, iters=iters, reps=reps)
        results["formats"][name] = r
        for b in buckets:
            rb = r["buckets"][str(b)]
            rows.append(f"{name},{b},{rb['wave_images_per_s']:.1f},"
                        f"{r['single_images_per_s']:.1f},"
                        f"{rb['speedup_vs_single']:.2f}")
    return "\n".join(rows), results


if __name__ == "__main__":
    text, _ = run()
    print(text)
