"""Lane-batched serving benchmark: wave throughput vs batch size vs the
one-request-at-a-time loop (DESIGN.md §10).

The carrier's pixel-row axis is the batch axis, so a wave of N images
runs through one compiled resident call whose fixed costs (dispatch,
one encode/decode, per-netlist op issue) are batch-invariant until the
plane arrays saturate the machine — serving cost per image falls with
occupancy.  This benchmark measures exactly that: for each batch
bucket B, a :class:`ConvServeEngine` serves B queued single-image
requests as one wave, against the baseline of B sequential
``graph.run`` calls on one image each (what callers paid before the
engine existed).  The engine path is timed end-to-end including its
host-side pack/unpack — the honest serving cost.

Emits ``BENCH_serve.json``: per format, the single-request baseline
and per-bucket wave timings with images/s, MACs/s, and the speedup vs
the one-at-a-time loop.  The acceptance trajectory expects throughput
to increase with bucket size, ≥2x at the largest bucket on hobflops8.

The second half (``bench_load``) is the robustness benchmark
(DESIGN.md §11): a seeded Poisson open-loop load generator drives one
engine per admission policy over a sim clock — queue waits advance in
simulated time, wave executions in *measured* wall time — and records
p50/p99 end-to-end latency, throughput, occupancy, shed counts, and
precision-degradation activations per offered-load point.  Three
policies are contrasted: ``greedy`` (legacy: close any non-empty
queue), ``deadline`` (deadline-or-full admission), and ``fill_only``
(close only on a full bucket) — the last shows the unbounded tail that
``wave_deadline_ms`` exists to cap, the first the throughput left on
the table by never batching.

Autotuned launch blocks come through the ``tuned_conv_blocks`` disk
cache (``serve_conv/cache.py``), so repeat benchmark runs skip the
sweep; override the cache path with ``HOBFLOPS_TUNE_CACHE``.
"""
from __future__ import annotations

import numpy as np

from benchmarks.network import _time_all
from repro.core.fpformat import HOBFLOPS_FORMATS
from repro.kernels.conv2d_bitslice.network import NetworkGraph
from repro.serve_conv import (ConvRequest, ConvServeEngine, QueueFullError,
                              RunnerCache, ServePolicy, tuned_conv_blocks)

# Serving workload: 3x3 conv -> pointwise conv -> 2x2 maxpool on a
# HW x HW x C image.  Small on purpose: per-image marginal cost is the
# fused gate-eval compute (scales with B*H*W rows), while the per-wave
# fixed cost (call dispatch, per-op launch, encode/decode) is
# batch-invariant — the request-batching regime the lane packer
# targets, analogous to small-image high-QPS traffic on a wide
# machine.  Larger images shift the balance toward marginal compute
# and the batching win shrinks toward 1x (see BENCH_network.json for
# the compute-bound trajectory).
HW_, C_ = 4, 4
BUCKETS = (1, 2, 4, 8, 16)


def build_serve_graph(fmt_name: str, hw: int = HW_, c: int = C_,
                      seed: int = 0, blocks: dict | None = None):
    """Returns (single [1,hw,hw,c] image, request rng, NetworkGraph).
    ``blocks`` pins tuned launch parameters on both conv nodes (the
    runners thread them into the kernel launch)."""
    fmt = HOBFLOPS_FORMATS[fmt_name]
    rng = np.random.default_rng(seed)
    g = NetworkGraph(fmt)
    c1 = g.conv("c1", g.input_name,
                (rng.standard_normal((3, 3, c, c)) * 0.3)
                .astype(np.float32), relu=True, blocks=blocks)
    c2 = g.conv("c2", c1,
                (rng.standard_normal((1, 1, c, c)) * 0.3)
                .astype(np.float32), relu=True, blocks=blocks)
    g.output(g.maxpool2d("head", c2, window=2))
    img = rng.standard_normal((1, hw, hw, c)).astype(np.float32)
    return img, rng, g


def bench_serve(fmt_name: str, hw: int = HW_, c: int = C_,
                buckets=BUCKETS, iters: int = 10, reps: int = 5,
                tune_path: str | None = None) -> dict:
    img, _, g0 = build_serve_graph(fmt_name, hw, c)
    blocks, _ = tuned_conv_blocks(
        img, g0._weights["c1"], fmt=HOBFLOPS_FORMATS[fmt_name],
        candidates=[{"c_unroll": 4, "m_block": m} for m in (8, 128)],
        iters=1, path=tune_path)
    # rebuild with the tuned blocks pinned on the conv nodes, so the
    # timed waves actually execute the tuned configuration
    img, rng, g = build_serve_graph(fmt_name, hw, c, blocks=blocks)
    macs = g.macs(img.shape)

    cache = RunnerCache()
    images = {b: [rng.standard_normal((hw, hw, c)).astype(np.float32)
                  for _ in range(b)] for b in buckets}
    engines = {b: ConvServeEngine(g, (hw, hw, c), max_batch=b,
                                  blocks=blocks, runner_cache=cache)
               for b in buckets}

    def serve(b):
        eng = engines[b]
        for i, im in enumerate(images[b]):
            eng.submit(ConvRequest(i, im))
        return eng.run()[-1].out

    largest = max(buckets)

    def single_loop():
        out = None
        for im in images[largest]:
            out = g.run(im[None])
        return out

    # One interleaved timing set: every bucket's wave AND the shared
    # one-request-at-a-time baseline ride the same reps, so machine
    # drift hits all contenders equally and the per-bucket throughput
    # trend is comparable (a per-bucket baseline re-measure showed 2x
    # cross-bucket drift on shared CPUs).
    fns = [lambda b=b: serve(b) for b in buckets] + [single_loop]
    times = _time_all(fns, iters, reps)
    dt_single = times[-1] / largest            # per image, one per call
    results = {}
    for b, dt_wave in zip(buckets, times):
        results[str(b)] = {
            "bucket": b,
            "wave_us": dt_wave * 1e6,
            "wave_images_per_s": b / dt_wave,
            "wave_macs_per_s": b * macs / dt_wave,
            "speedup_vs_single": b * dt_single / dt_wave,
            "occupancy": engines[b].stats()["mean_occupancy"],
        }
    return {"format": fmt_name, "hw": hw, "c": c,
            "macs_per_image": macs, "blocks": blocks,
            "single_us_per_image": dt_single * 1e6,
            "single_images_per_s": 1.0 / dt_single,
            "single_macs_per_s": macs / dt_single,
            "buckets": results}


def smoke(fmt_name: str = "hobflops8", hw: int = 6, c: int = 4) -> dict:
    """Tier-1 smoke: a tiny graph serves 5 queued requests across a
    ragged wave split and every output is bit-identical to the
    per-request ``graph.run``."""
    img, rng, g = build_serve_graph(fmt_name, hw, c)
    eng = ConvServeEngine(g, (hw, hw, c), max_batch=4)
    reqs = [ConvRequest(i, rng.standard_normal((hw, hw, c))
                        .astype(np.float32)) for i in range(5)]
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert len(done) == 5 and eng.waves == 2     # 4 + ragged 1
    for r in done:
        solo = np.asarray(g.run(r.image[None]))[0]
        assert (r.out == solo).all(), f"request {r.rid} not bit-exact"
    st = eng.stats()
    assert st["images_served"] == 5
    return st


class _SimClock:
    """Injectable engine clock: queue waits pass in simulated seconds,
    wave executions are fed back as their *measured* wall time."""

    def __init__(self):
        self.now = 0.0

    def __call__(self) -> float:
        return self.now

    def advance(self, s: float):
        self.now += s


def _load_policy(kind: str, deadline_ms: float,
                 max_queue: int) -> ServePolicy:
    deadline = {"greedy": None, "deadline": deadline_ms,
                "fill_only": 1e9}[kind]
    return ServePolicy(wave_deadline_ms=deadline,
                       max_queue_images=max_queue,
                       degrade_queue_factor=2.0, degrade_patience=2,
                       recover_patience=2)


def _drive(eng, clock, arrivals, images) -> list:
    """Open-loop event simulation: submit each arrival at its Poisson
    timestamp, close waves per the engine's own admission policy, and
    advance the sim clock by the measured execution time of every wave
    (a single-threaded server is busy while a wave runs).  Returns the
    served requests; sheds/quarantines stay on the engine's counters."""
    served, i = [], 0
    while i < len(arrivals) or eng.pending_images():
        # admit every arrival that already happened in sim time — a
        # wave execution is a busy period, and all requests that
        # arrived during it are queued before the next wave closes
        while i < len(arrivals) and arrivals[i] <= clock.now:
            try:
                eng.submit(ConvRequest(i, images[i]))
            except QueueFullError:
                pass                      # engine counted the shed
            i += 1
        if eng.pending_images() and eng.wave_ready():
            out = eng.step()
            if out:
                clock.advance(eng.wave_seconds[-1])
                served.extend(out)
            continue
        next_arrival = arrivals[i] if i < len(arrivals) else None
        if next_arrival is None:
            # trace over: flush the partial tail (fill_only would
            # otherwise hold it for its ~infinite deadline)
            while eng.pending_images():
                out = eng.step(force=True)
                if out:
                    clock.advance(eng.wave_seconds[-1])
                    served.extend(out)
            break
        deadline = eng.next_deadline() if eng.pending_images() else None
        if deadline is not None and deadline < next_arrival:
            # epsilon past the deadline: float rounding in the
            # absolute-deadline reconstruction must not leave the
            # oldest wait a hair under the threshold (livelock)
            clock.now = max(clock.now, deadline) + 1e-6
        else:
            clock.now = max(clock.now, next_arrival)
    return served


def bench_load(fmt_name: str = "hobflops9", degrade_to: str = "hobflops8",
               hw: int = HW_, c: int = C_, max_batch: int = 8,
               load_factors=(0.25, 0.5, 1.0, 2.0, 4.0),
               n_requests: int = 200, seed: int = 7) -> dict:
    """Poisson offered load vs p50/p99 latency per admission policy.

    Offered load is expressed as a multiple of the engine's measured
    full-bucket capacity (images/s); the degradation ladder registers a
    ``with_precision(degrade_to)`` variant so sustained overload sheds
    precision before shedding requests."""
    fmt = HOBFLOPS_FORMATS[fmt_name]
    img, rng, g = build_serve_graph(fmt_name, hw, c, seed=seed)
    g_deg = g.with_precision(HOBFLOPS_FORMATS[degrade_to])
    hwc = (hw, hw, c)
    cache = RunnerCache()

    # Warm every (variant, bucket) runner through the shared cache so
    # jit compile time never pollutes a simulated latency sample, and
    # measure full-bucket capacity while we're at it.
    wave_s = None
    for graph in (g, g_deg):
        eng = ConvServeEngine(graph, hwc, max_batch=max_batch,
                              runner_cache=cache)
        for b in eng.buckets:
            for rep in range(3 if b == max_batch else 1):
                for i in range(b):
                    eng.submit(ConvRequest(i, rng.standard_normal(hwc)
                                           .astype(np.float32)))
                eng.run()
        if graph is g:
            wave_s = min(s for s, o in zip(eng.wave_seconds,
                                           eng.wave_occupancy)
                         if o == 1.0)
    capacity = max_batch / wave_s
    # one full-wave service time: a lone request waits at most one
    # wave's worth before closing, while full buckets still close on
    # fullness — the throughput/latency dial at a latency-ish setting
    deadline_ms = wave_s * 1e3

    images = [rng.standard_normal(hwc).astype(np.float32)
              for _ in range(n_requests)]
    points = []
    for load in load_factors:
        lam = load * capacity                        # images/s offered
        arrivals = np.cumsum(rng.exponential(1.0 / lam, n_requests))
        row = {"load_factor": load, "offered_images_per_s": lam}
        for kind in ("greedy", "deadline", "fill_only"):
            clock = _SimClock()
            eng = ConvServeEngine(
                g, hwc, max_batch=max_batch, runner_cache=cache,
                clock=clock,
                policy=_load_policy(kind, deadline_ms,
                                    max_queue=8 * max_batch))
            eng.register_degraded(g_deg, degrade_to)
            served = _drive(eng, clock, arrivals, images)
            st = eng.stats()
            row[kind] = {
                "served": len(served),
                "shed": st["requests_shed"],
                "throughput_images_per_s": len(served) / clock.now,
                "p50_ms": st["p50_latency_ms"],
                "p99_ms": st["p99_latency_ms"],
                "mean_occupancy": st["mean_occupancy"],
                "mean_wave_images": (st["images_served"] / st["waves"]
                                     if st["waves"] else 0.0),
                "degrade_activations": st["degradation"]["activations"],
                "images_degraded": sum(
                    v for k, v in
                    st["degradation"]["images_by_level"].items()
                    if k != "full"),
            }
        points.append(row)
    return {"format": fmt_name, "degrade_to": degrade_to, "hw": hw,
            "c": c, "max_batch": max_batch, "n_requests": n_requests,
            "capacity_images_per_s": capacity,
            "wave_deadline_ms": deadline_ms, "points": points}


def run(quick: bool = False):
    formats = ["hobflops8", "hobflops9"]
    buckets = BUCKETS if not quick else (1, 2, 4, 8)
    iters, reps = (4, 3) if quick else (10, 5)
    rows = ["format,bucket,wave_images_per_s,single_images_per_s,"
            "speedup_vs_single"]
    results = {"workload": {"hw": HW_, "c": C_, "buckets": list(buckets)},
               "formats": {}}
    for name in formats:
        r = bench_serve(name, buckets=buckets, iters=iters, reps=reps)
        results["formats"][name] = r
        for b in buckets:
            rb = r["buckets"][str(b)]
            rows.append(f"{name},{b},{rb['wave_images_per_s']:.1f},"
                        f"{r['single_images_per_s']:.1f},"
                        f"{rb['speedup_vs_single']:.2f}")
    load = bench_load(max_batch=4 if quick else 8,
                      load_factors=(0.5, 2.0) if quick
                      else (0.25, 0.5, 1.0, 2.0, 4.0),
                      n_requests=40 if quick else 200)
    results["load"] = load
    rows.append("policy,load_factor,p50_ms,p99_ms,throughput_images_per_s,"
                "shed,images_degraded")
    for point in load["points"]:
        for kind in ("greedy", "deadline", "fill_only"):
            p = point[kind]
            rows.append(f"{kind},{point['load_factor']},"
                        f"{p['p50_ms']:.3f},{p['p99_ms']:.3f},"
                        f"{p['throughput_images_per_s']:.1f},"
                        f"{p['shed']},{p['images_degraded']}")
    return "\n".join(rows), results


if __name__ == "__main__":
    text, _ = run()
    print(text)
