"""Paper Figs 7a/7b (Neon), 8b (AVX2), 9b (AVX512): MAC gate counts vs
precision, per cell library, per rounding mode — plus our TPU-VPU
library.  The paper's claim that synthesis area tracks software op count
(and hence throughput) is checked against the macs.py measurements.

Counts come from the post-mapping optimization pipeline
(``opt.optimize_mapped``: const-prop, remap iteration, ANDN absorption,
dead-node sweep) — the Genus+ABC area pass of the flow.  A second table
reports the fused K-step chain (``build_mac_chain``) as gates/MAC
against K independent MACs, the paper's "share the netlist across the
dot product" lever (DESIGN.md §3).
"""
from __future__ import annotations

import time

from repro.core.fpcore import build_mac, build_mac_chain
from repro.core.fpformat import HOBFLOPS_FORMATS, RNE, RTZ
from repro.core.opt import lib_gate_count, optimize_mapped

LIBS = ("avx2", "neon", "avx512", "tpu_vpu")
FORMATS = ["hobflops8", "hobflops9", "hobflops10", "hobflops11",
           "hobflops12", "hobflops13", "hobflops14", "hobflops15",
           "hobflops16", "hobflops_ieee8"]
CHAIN_K = 4


def gate_table(extended: bool = False, roundings=(RNE, RTZ),
               formats=FORMATS):
    rows = []
    for name in formats:
        fmt = HOBFLOPS_FORMATS[name]
        for rounding in roundings:
            t0 = time.time()
            g = build_mac(fmt, extended=extended, rounding=rounding)
            row = {"format": name + ("e" if extended else ""),
                   "rounding": rounding,
                   "raw_gates": g.live_gate_count(),
                   "depth": g.depth(),
                   "build_s": round(time.time() - t0, 2)}
            for lib in LIBS:
                row[lib] = lib_gate_count(optimize_mapped(g, lib), lib)
            rows.append(row)
    return rows


def chain_table(formats, k: int = CHAIN_K, rounding: str = RNE,
                extended: bool = False, mac_gates: dict | None = None):
    """Gates/MAC of the fused k-step chain vs k independent MACs.

    ``mac_gates`` maps (format, lib) -> already-computed single-MAC
    optimized gate count (from :func:`gate_table`) to avoid re-running
    the mapper on the same netlists."""
    rows = []
    for name in formats:
        fmt = HOBFLOPS_FORMATS[name]
        row = {"format": name, "k": k, "rounding": rounding}
        for lib in LIBS:
            single = (mac_gates or {}).get((name, lib))
            if single is None:
                single = lib_gate_count(
                    optimize_mapped(build_mac(fmt, extended, rounding),
                                    lib), lib)
            chain = lib_gate_count(
                optimize_mapped(build_mac_chain(fmt, k, extended, rounding),
                                lib), lib)
            row[lib] = {
                "mac_gates": single,
                "chain_gates_per_mac": chain / k,
                "saving_pct": 100.0 * (k * single - chain) / (k * single),
            }
        rows.append(row)
    return rows


def run(quick: bool = False):
    formats = (["hobflops8", "hobflops9", "hobflops16"] if quick
               else FORMATS)
    rows = gate_table(formats=formats)
    rows += gate_table(extended=True, roundings=(RNE,),
                       formats=["hobflops8", "hobflops9", "hobflops16"])
    out = ["format,rounding,raw,avx2,neon,avx512,tpu_vpu,depth"]
    for r in rows:
        out.append(f"{r['format']},{r['rounding']},{r['raw_gates']},"
                   f"{r['avx2']},{r['neon']},{r['avx512']},"
                   f"{r['tpu_vpu']},{r['depth']}")

    chain_formats = ["hobflops8", "hobflops9", "hobflops16"]
    mac_gates = {(r["format"], lib): r[lib] for r in rows
                 if r["rounding"] == RNE and not r["format"].endswith("e")
                 for lib in LIBS}
    chains = chain_table(chain_formats, mac_gates=mac_gates)
    out.append("")
    out.append("format,k,lib,mac_gates,chain_gates_per_mac,saving_pct")
    for r in chains:
        for lib in LIBS:
            c = r[lib]
            out.append(f"{r['format']},{r['k']},{lib},{c['mac_gates']},"
                       f"{c['chain_gates_per_mac']:.1f},"
                       f"{c['saving_pct']:.1f}")

    results = {"mac": rows, "chain": chains, "chain_k": CHAIN_K,
               "libs": list(LIBS)}
    return "\n".join(out), results


if __name__ == "__main__":
    text, _ = run()
    print(text)
