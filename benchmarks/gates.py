"""Paper Figs 7a/7b (Neon), 8b (AVX2), 9b (AVX512): MAC gate counts vs
precision, per cell library, per rounding mode — plus our TPU-VPU
library.  The paper's claim that synthesis area tracks software op count
(and hence throughput) is checked against the macs.py measurements.
"""
from __future__ import annotations

import time

from repro.core.fpcore import build_mac
from repro.core.fpformat import HOBFLOPS_FORMATS, RNE, RTZ
from repro.core.opt import CELL_LIBS, tech_map

LIBS = ("avx2", "neon", "avx512", "tpu_vpu")
FORMATS = ["hobflops8", "hobflops9", "hobflops10", "hobflops11",
           "hobflops12", "hobflops13", "hobflops14", "hobflops15",
           "hobflops16", "hobflops_ieee8"]


def gate_table(extended: bool = False, roundings=(RNE, RTZ),
               formats=FORMATS):
    rows = []
    for name in formats:
        fmt = HOBFLOPS_FORMATS[name]
        for rounding in roundings:
            t0 = time.time()
            g = build_mac(fmt, extended=extended, rounding=rounding)
            row = {"format": name + ("e" if extended else ""),
                   "rounding": rounding,
                   "raw_gates": g.live_gate_count(),
                   "depth": g.depth(),
                   "build_s": round(time.time() - t0, 2)}
            for lib in LIBS:
                mapped = tech_map(g, CELL_LIBS[lib]())
                row[lib] = mapped.live_gate_count()
            rows.append(row)
    return rows


def run(quick: bool = False):
    formats = (["hobflops8", "hobflops9", "hobflops16"] if quick
               else FORMATS)
    rows = gate_table(formats=formats)
    rows += gate_table(extended=True, roundings=(RNE,),
                       formats=["hobflops8", "hobflops9", "hobflops16"])
    out = ["format,rounding,raw,avx2,neon,avx512,tpu_vpu,depth"]
    for r in rows:
        out.append(f"{r['format']},{r['rounding']},{r['raw_gates']},"
                   f"{r['avx2']},{r['neon']},{r['avx512']},"
                   f"{r['tpu_vpu']},{r['depth']}")
    return "\n".join(out), rows


if __name__ == "__main__":
    text, _ = run()
    print(text)
