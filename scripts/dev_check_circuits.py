"""Dev script: exhaustive circuit-vs-oracle check for small formats.

Runs the FloPoCo-testbench analogue: every canonical operand pair
through the gate-level netlists vs the softfloat oracle, plus a fused
MAC-chain vs sequential-MAC equivalence sweep.  Importable (the tier-1
suite runs :func:`run_checks` via ``tests/test_tooling.py``) and
runnable standalone::

    python scripts/dev_check_circuits.py [--quick]
"""
import argparse
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                os.pardir, "src"))

from repro.core import softfloat as sf
from repro.core.bitslice import pack_planes_np, unpack_planes_np
from repro.core.codegen import eval_netlist
from repro.core.fpcore import (build_add, build_cast, build_mac,
                               build_mac_chain, build_max, build_mul,
                               build_scale)
from repro.core.fpformat import (EXC_INF, EXC_NAN, EXC_NORMAL, EXC_ZERO, RNE,
                                 RTZ, FPFormat)


def all_canonical_codes(fmt):
    codes = []
    for exc, signs in ((EXC_ZERO, (0, 1)), (EXC_INF, (0, 1)), (EXC_NAN, (0,))):
        for s in signs:
            codes.append(sf.pack(exc, s, 0, 0, fmt))
    n_norm = 2 * (1 << fmt.w_e) * (1 << fmt.w_f)
    sign = np.repeat([0, 1], n_norm // 2)
    exp = np.tile(np.repeat(np.arange(1 << fmt.w_e), 1 << fmt.w_f), 2)
    frac = np.tile(np.arange(1 << fmt.w_f), 2 * (1 << fmt.w_e))
    codes.extend(sf.pack(np.full(n_norm, EXC_NORMAL), sign, exp, frac, fmt))
    return np.array(codes, dtype=np.int64)


def check(fmt_in, fmt_out, rounding, op):
    xs = all_canonical_codes(fmt_in)
    pairs_x = np.repeat(xs, len(xs))
    pairs_y = np.tile(xs, len(xs))
    if op == "mul":
        expect = sf.fp_mul(pairs_x, pairs_y, fmt_in, fmt_out, rounding)
        g = build_mul(fmt_in, fmt_out, rounding)
    else:
        expect = sf.fp_add(pairs_x, pairs_y, fmt_in, rounding)
        g = build_add(fmt_in, rounding)
    planes_x = pack_planes_np(pairs_x, fmt_in.nbits)
    planes_y = pack_planes_np(pairs_y, fmt_in.nbits)
    out = eval_netlist(g, {"x": planes_x, "y": planes_y})["out"]
    got = unpack_planes_np(out, len(pairs_x))
    bad = got != expect
    print(f"{op} {fmt_in}->{fmt_out} {rounding}: {len(pairs_x)} pairs, "
          f"{bad.sum()} mismatches, gates={g.live_gate_count()} "
          f"depth={g.depth()}")
    if bad.any():
        idx = np.nonzero(bad)[0][:10]
        for i in idx:
            print(f"  x={pairs_x[i]:x} ({sf.decode(pairs_x[i], fmt_in)}) "
                  f"y={pairs_y[i]:x} ({sf.decode(pairs_y[i], fmt_in)}) "
                  f"got={got[i]:x} ({sf.decode(got[i], fmt_out)}) "
                  f"want={expect[i]:x} ({sf.decode(expect[i], fmt_out)})")
        return False
    return True


def check_cast(fmt_in, fmt_out, rounding):
    """Exhaustive: build_cast == softfloat.fp_cast over every canonical
    code (the inter-layer boundary op of the resident pipeline)."""
    xs = all_canonical_codes(fmt_in)
    g = build_cast(fmt_in, fmt_out, rounding)
    out = eval_netlist(g, {"x": pack_planes_np(xs, fmt_in.nbits)})["out"]
    got = unpack_planes_np(out, len(xs))
    expect = sf.fp_cast(xs, fmt_in, fmt_out, rounding)
    bad = got != expect
    print(f"cast {fmt_in}->{fmt_out} {rounding}: {len(xs)} codes, "
          f"{bad.sum()} mismatches, gates={g.live_gate_count()}")
    if bad.any():
        for i in np.nonzero(bad)[0][:10]:
            print(f"  x={xs[i]:x} ({sf.decode(xs[i], fmt_in)}) "
                  f"got={got[i]:x} want={expect[i]:x}")
        return False
    return True


def check_max(fmt):
    """Exhaustive pairs: build_max == softfloat.fp_max (the plane-domain
    maxpool reduction)."""
    xs = all_canonical_codes(fmt)
    pairs_x = np.repeat(xs, len(xs))
    pairs_y = np.tile(xs, len(xs))
    g = build_max(fmt)
    out = eval_netlist(g, {"x": pack_planes_np(pairs_x, fmt.nbits),
                           "y": pack_planes_np(pairs_y, fmt.nbits)})["out"]
    got = unpack_planes_np(out, len(pairs_x))
    expect = sf.fp_max(pairs_x, pairs_y, fmt)
    bad = got != expect
    print(f"max {fmt}: {len(pairs_x)} pairs, {bad.sum()} mismatches, "
          f"gates={g.live_gate_count()}")
    if bad.any():
        for i in np.nonzero(bad)[0][:10]:
            print(f"  x={pairs_x[i]:x} ({sf.decode(pairs_x[i], fmt)}) "
                  f"y={pairs_y[i]:x} ({sf.decode(pairs_y[i], fmt)}) "
                  f"got={got[i]:x} want={expect[i]:x}")
        return False
    return True


def check_scale(fmt, k):
    """Exhaustive: build_scale == softfloat.fp_scale (the divider-free
    avgpool tail, x * 2**-k)."""
    xs = all_canonical_codes(fmt)
    g = build_scale(fmt, k)
    out = eval_netlist(g, {"x": pack_planes_np(xs, fmt.nbits)})["out"]
    got = unpack_planes_np(out, len(xs))
    expect = sf.fp_scale(xs, k, fmt)
    bad = got != expect
    print(f"scale {fmt} k={k}: {len(xs)} codes, {bad.sum()} mismatches, "
          f"gates={g.live_gate_count()}")
    if bad.any():
        for i in np.nonzero(bad)[0][:10]:
            print(f"  x={xs[i]:x} ({sf.decode(xs[i], fmt)}) "
                  f"got={got[i]:x} want={expect[i]:x}")
        return False
    return True


def check_chain(fmt_in, k, rounding=RNE, n=8192, seed=0):
    """Random-vector equivalence: build_mac_chain == k x build_mac."""
    fmt_out = fmt_in.mult_out()
    rng = np.random.default_rng(seed)
    cc = all_canonical_codes(fmt_in)
    co = all_canonical_codes(fmt_out)
    xs = [cc[rng.integers(0, len(cc), n)] for _ in range(k)]
    ys = [cc[rng.integers(0, len(cc), n)] for _ in range(k)]
    acc = co[rng.integers(0, len(co), n)]

    g1 = build_mac(fmt_in, rounding=rounding)
    cur = acc
    for x, y in zip(xs, ys):
        planes = {"x": pack_planes_np(x, fmt_in.nbits),
                  "y": pack_planes_np(y, fmt_in.nbits),
                  "acc": pack_planes_np(cur, fmt_out.nbits)}
        cur = unpack_planes_np(eval_netlist(g1, planes)["out"], n)

    gc = build_mac_chain(fmt_in, k, rounding=rounding)
    planes = {f"x{i}": pack_planes_np(xs[i], fmt_in.nbits) for i in range(k)}
    planes |= {f"y{i}": pack_planes_np(ys[i], fmt_in.nbits) for i in range(k)}
    planes["acc"] = pack_planes_np(acc, fmt_out.nbits)
    got = unpack_planes_np(eval_netlist(gc, planes)["out"], n)
    bad = int((got != cur).sum())
    print(f"mac-chain {fmt_in} k={k} {rounding}: {n} vectors, "
          f"{bad} mismatches, gates={gc.live_gate_count()} "
          f"(k*mac={k * g1.live_gate_count()})")
    return bad == 0


def run_checks(quick: bool = False) -> bool:
    ok = True
    f32 = FPFormat(3, 2)
    ok &= check(f32, f32.mult_out(), RNE, "mul")
    ok &= check(FPFormat(3, 3), FPFormat(3, 3), RNE, "add")
    ok &= check_chain(f32, 2, RNE)
    # accumulator-format -> operand-format cast (the layer boundary)
    ok &= check_cast(f32.mult_out(), f32, RNE)
    # graph-runner node netlists: maxpool reduction + avgpool scale
    ok &= check_max(f32)
    ok &= check_scale(f32, 2)
    if not quick:
        ok &= check(f32, f32.mult_out(True), RNE, "mul")
        ok &= check(f32, f32.mult_out(), RTZ, "mul")
        ok &= check(FPFormat(3, 3), FPFormat(3, 3), RTZ, "add")
        ok &= check(FPFormat(4, 2), FPFormat(4, 2), RNE, "add")
        ok &= check_chain(f32, 4, RTZ)
        ok &= check_chain(FPFormat(5, 2), 4, RNE)
        ok &= check_cast(f32.mult_out(), f32, RTZ)
        ok &= check_cast(FPFormat(5, 3).mult_out(), FPFormat(5, 2), RNE)
        ok &= check_cast(FPFormat(3, 2), FPFormat(4, 4), RNE)
        ok &= check_max(FPFormat(4, 2))
        ok &= check_max(FPFormat(5, 3).mult_out())  # accumulator-fmt pool
        ok &= check_scale(FPFormat(4, 2), 1)
        ok &= check_scale(FPFormat(5, 3).mult_out(), 3)
        ok &= check_scale(f32, 0)
    return ok


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    ok = run_checks(quick=args.quick)
    print("ALL OK" if ok else "FAILURES")
    sys.exit(0 if ok else 1)
