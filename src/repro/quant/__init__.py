"""HOBFLOPS weight quantization for the LM stack.

Storage layouts for custom-precision FP weights (the paper's "fast
custom-precision FP ... valuable in cases where memory bandwidth is
limited", adapted to TPU serving):

* ``"native"``    — one code per int8/int16 element.  Cheap dequant
                    (~8 VPU ops/elem) but rounds the footprint up to the
                    container width.
* ``"bitplane"``  — the paper's bitslice layout: exactly ``nbits`` bits
                    per weight in HBM (e.g. 9 bits for HOBFLOPS9), at a
                    higher dequant cost.  This is where sub-byte formats
                    actually pay off on the memory roofline term.
"""
from .storage import (QuantizedTensor, dequantize, quantize,
                      storage_bytes)
from .apply import make_deq, quantize_params, quantized_bytes

__all__ = ["QuantizedTensor", "quantize", "dequantize", "storage_bytes",
           "quantize_params", "make_deq", "quantized_bytes"]
