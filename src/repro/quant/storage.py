"""Quantized weight tensors: HOBFLOPS codes in native or bitplane layout."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import softfloat as sf
from repro.core.fpformat import RNE, StorageFormat

LANE = 32


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class QuantizedTensor:
    """A weight tensor stored as HOBFLOPS StorageFormat codes.

    layout "native":   data is int8/int16 with `shape`.
    layout "bitplane": data is int32 [nbits, prod(shape)/32] bit planes.
    """
    data: Any
    scale: Any  # f32 per-tensor scale (power-of-two friendly but free-form)
    sfmt: StorageFormat = dataclasses.field(metadata=dict(static=True))
    layout: str = dataclasses.field(metadata=dict(static=True))
    shape: tuple = dataclasses.field(metadata=dict(static=True))

    @property
    def dtype(self):
        return jnp.float32

    def nbytes_hbm(self) -> int:
        return storage_bytes(self.shape, self.sfmt, self.layout)


def storage_bytes(shape, sfmt: StorageFormat, layout: str) -> int:
    import math
    n = math.prod(shape)
    if layout == "native":
        return n * (1 if sfmt.container() == "int8" else 2)
    return -(-n * sfmt.nbits // 8)  # true bit packing


def quantize(w, sfmt: StorageFormat, layout: str = "native",
             rounding: str = RNE, scale=None) -> QuantizedTensor:
    """Quantize float weights.  `scale` defaults to amax-based so the
    largest weight maps near the top of the format's range."""
    w = jnp.asarray(w, jnp.float32)
    if scale is None:
        amax = jnp.maximum(jnp.max(jnp.abs(w)), 1e-30)
        # place amax at ~half the max representable magnitude
        target = 2.0 ** (sfmt.emax - sfmt.bias - 1)
        scale = amax / target
    codes = sf.encode_storage(w / scale, sfmt, rounding)
    if layout == "native":
        ct = jnp.int8 if sfmt.container() == "int8" else jnp.int16
        data = codes.astype(ct)
    elif layout == "bitplane":
        flat = codes.reshape(-1)
        pad = (-flat.shape[0]) % LANE
        flat = jnp.pad(flat, (0, pad))
        from repro.core.bitslice import pack_planes
        data = pack_planes(flat, sfmt.nbits)       # [nbits, n/32] int32
    else:
        raise ValueError(layout)
    return QuantizedTensor(data=data, scale=jnp.float32(scale), sfmt=sfmt,
                           layout=layout, shape=tuple(w.shape))


def dequantize(qt: QuantizedTensor):
    """-> float32 tensor of qt.shape (the pure-jnp reference path)."""
    import math
    n = math.prod(qt.shape)
    if qt.layout == "native":
        codes = qt.data.astype(jnp.int32)
    elif qt.layout == "bitplane2d":
        # [nbits, K, N//32] planes (shardable along K and N//32)
        from repro.core.bitslice import unpack_planes
        nbits, K, Nw = qt.data.shape
        codes = unpack_planes(qt.data.reshape(nbits, K * Nw))
        codes = codes.reshape(K, Nw * LANE)
    else:
        from repro.core.bitslice import unpack_planes
        codes = unpack_planes(qt.data)[:n].reshape(qt.shape)
    vals = sf.decode_storage(codes, qt.sfmt)
    return vals.reshape(qt.shape) * qt.scale
