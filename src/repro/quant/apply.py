"""Apply HOBFLOPS weight quantization to a model parameter tree.

Targets every >=2D projection matrix in the transformer blocks (plus
logits head and modality projector); embeddings, norms, biases and the
tiny precision-sensitive SSM params (conv, dt, A, D) stay in full
precision.  Stacked (scanned) weights are packed PER LAYER so that
``lax.scan`` can slice the leading depth axis of the bitplane tensor —
the QuantizedTensor's static ``shape`` records the per-layer shape.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.fpformat import StorageFormat, parse_format
from repro.models.config import ModelConfig

from .storage import LANE, QuantizedTensor, dequantize, quantize

# weight names eligible for quantized storage
_TARGETS = {"wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down",
            "in_proj", "out_proj", "w"}
_SKIP_PARENTS = {"embed"}


def _sfmt(fmt_name: str) -> StorageFormat:
    f = parse_format(fmt_name)
    return StorageFormat(f.w_e, f.w_f)


def quantize_leaf(w, sfmt: StorageFormat, stacked: bool):
    """Quantize one tensor; if `stacked`, pack each leading-axis slice
    separately so scan slicing stays valid."""
    if not stacked:
        return quantize(w, sfmt, layout="bitplane")
    per = [quantize(w[i], sfmt, layout="bitplane")
           for i in range(w.shape[0])]
    return QuantizedTensor(
        data=jnp.stack([q.data for q in per]),
        scale=jnp.stack([q.scale for q in per]),
        sfmt=sfmt, layout="bitplane", shape=tuple(w.shape[1:]))


def _plane2d_shape(shape, sfmt: StorageFormat):
    """Bitplane-2D layout: [..., K, N] -> [..., nbits, K, N // 32]."""
    *lead, K, N = shape
    assert N % LANE == 0
    return tuple(lead) + (sfmt.nbits, K, N // LANE)


def abstract_quantize_params(abstract_params, cfg: ModelConfig,
                             fmt_name: str):
    """ShapeDtypeStruct tree -> same tree with target weights replaced
    by abstract QuantizedTensors (bitplane-2D, shardable along K and
    N//32).  Used by the dry-run: nothing is allocated."""
    sfmt = _sfmt(fmt_name)

    def walk(tree, path):
        if isinstance(tree, dict):
            return {k: walk(v, path + (k,)) for k, v in tree.items()}
        name = path[-1] if path else ""
        parent = path[-2] if len(path) > 1 else ""
        in_blocks = any(p in ("blocks", "enc_blocks", "logits", "frontend")
                        for p in path)
        if (in_blocks and name in _TARGETS
                and parent not in _SKIP_PARENTS
                and len(tree.shape) >= 2 and tree.shape[-1] % LANE == 0):
            lead = tree.shape[:-2]
            return QuantizedTensor(
                data=jax.ShapeDtypeStruct(
                    _plane2d_shape(tree.shape, sfmt), jnp.int32),
                scale=jax.ShapeDtypeStruct(lead, jnp.float32),
                sfmt=sfmt, layout="bitplane2d",
                shape=tuple(tree.shape[-2:]))
        return tree

    return walk(abstract_params, ())


def quantized_pspecs(pspec_tree, qparams_tree):
    """Map the dense-param PartitionSpec tree onto the quantized tree:
    a leaf spec (*lead, K_ax, N_ax) becomes data (*lead, None, K_ax,
    N_ax) (planes replicated, K/N//32 inherit) and scale (*lead,)."""
    from jax.sharding import PartitionSpec

    def walk(spec, q):
        if isinstance(q, dict):
            return {k: walk(spec[k], q[k]) for k in q}
        if isinstance(q, QuantizedTensor):
            parts = list(spec)
            parts += [None] * (len(q.data.shape) - 1 - len(parts))
            lead, k_ax, n_ax = parts[:-2], parts[-2], parts[-1]
            return QuantizedTensor(
                data=PartitionSpec(*lead, None, k_ax, n_ax),
                scale=PartitionSpec(*lead),
                sfmt=q.sfmt, layout=q.layout, shape=q.shape)
        return spec

    return walk(pspec_tree, qparams_tree)


def quantize_params(params, cfg: ModelConfig, fmt_name: str):
    """-> (new_params, deq_hook).  Weights under blocks/enc_blocks (and
    the logits/frontend heads) move to bitplane storage."""
    sfmt = _sfmt(fmt_name)

    def walk(tree, path):
        if isinstance(tree, dict):
            return {k: walk(v, path + (k,)) for k, v in tree.items()}
        name = path[-1] if path else ""
        parent = path[-2] if len(path) > 1 else ""
        in_blocks = any(p in ("blocks", "enc_blocks", "logits", "frontend")
                        for p in path)
        if (in_blocks and name in _TARGETS
                and parent not in _SKIP_PARENTS
                and hasattr(tree, "ndim") and tree.ndim >= 2
                and math.prod(tree.shape[-2:]) % LANE == 0):
            stacked = any(p.startswith("b") and p[1:].isdigit()
                          for p in path) or "e0" in path
            stacked = stacked and tree.ndim >= 3
            return quantize_leaf(tree, sfmt, stacked)
        return tree

    new_params = walk(params, ())
    return new_params, make_deq()


def make_deq():
    """The dequant hook the layers call: (name, maybe-quantized) ->
    dense array."""
    def deq(name, x):
        if isinstance(x, QuantizedTensor):
            return dequantize(x)
        return x
    return deq


def quantized_bytes(params) -> tuple[int, int]:
    """(bytes_quantized_storage, bytes_if_bf16) over quantized leaves."""
    q_bytes = 0
    d_bytes = 0

    def walk(tree):
        nonlocal q_bytes, d_bytes
        if isinstance(tree, dict):
            for v in tree.values():
                walk(v)
        elif isinstance(tree, QuantizedTensor):
            n_layers = (tree.data.shape[0] if tree.data.ndim == 3 else 1)
            q_bytes += tree.data.size * 4 + tree.scale.size * 4
            d_bytes += n_layers * math.prod(tree.shape) * 2
    walk(params)
    return q_bytes, d_bytes
