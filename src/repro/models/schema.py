"""Parameter schemas: one source of truth for shapes, init and sharding.

A model describes its parameters as a nested dict of :class:`P` leaves
(shape + logical axes + init rule).  From that single schema we derive:

* ``init_params``      — materialized arrays (host; small configs only)
* ``abstract_params``  — ``jax.ShapeDtypeStruct`` tree (dry-run: the 405B
                         configs are never allocated)
* ``pspecs``           — ``PartitionSpec`` tree via logical-axis rules
                         with divisibility-aware fallback to replication

Logical axes used by the model stack:

  embed   d_model-sized dims         -> FSDP over the data(+pod) axes
  vocab   (padded) vocabulary        -> "model"
  qheads  fused n_heads*d_head       -> "model"
  kvheads fused n_kv*d_head          -> "model" (replicated when too few)
  mlp     d_ff                       -> "model"
  experts MoE expert count           -> "model" (EP) when divisible
  ssm     SSD inner features/heads   -> "model"
  layers  scan-stacked leading dim   -> never sharded
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec

Tree = Any


@dataclasses.dataclass(frozen=True)
class P:
    shape: tuple[int, ...]
    axes: tuple[Any, ...]          # logical name / None per dim
    init: str = "normal"           # normal | zeros | ones
    scale: float | None = None     # stddev for normal (default fan-in)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def stack(schema: Tree, n: int) -> Tree:
    """Prepend an unsharded leading 'layers' dim of size n to every leaf."""
    return jax.tree.map(
        lambda p: P((n,) + p.shape, ("layers",) + p.axes, p.init, p.scale),
        schema, is_leaf=lambda x: isinstance(x, P))


def _leaf_init(p: P, key, dtype):
    if p.init == "zeros":
        return jnp.zeros(p.shape, dtype)
    if p.init == "ones":
        return jnp.ones(p.shape, dtype)
    assert p.init == "normal", p.init
    fan_in = p.shape[-2] if len(p.shape) >= 2 else p.shape[-1]
    std = p.scale if p.scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
    return (std * jax.random.normal(key, p.shape, jnp.float32)).astype(dtype)


def init_params(schema: Tree, key, dtype=jnp.float32) -> Tree:
    leaves, treedef = jax.tree.flatten(
        schema, is_leaf=lambda x: isinstance(x, P))
    keys = jax.random.split(key, len(leaves))
    vals = [_leaf_init(p, k, dtype) for p, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(schema: Tree, dtype=jnp.float32) -> Tree:
    return jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, dtype),
        schema, is_leaf=lambda x: isinstance(x, P))


def param_count(schema: Tree) -> int:
    leaves = jax.tree.leaves(schema, is_leaf=lambda x: isinstance(x, P))
    return sum(math.prod(p.shape) for p in leaves)


# ---------------------------------------------------------------------------
# Sharding rules
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Rules:
    """logical axis -> mesh axis (str, tuple, or candidate list).
    ``sizes`` maps mesh axis name -> size for divisibility checks.
    A list value holds fallback candidates tried in order (first whose
    axes are unused and divide the dim wins)."""
    table: dict
    sizes: dict

    def resolve(self, logical, dim: int, used=frozenset()):
        cands = self.table.get(logical)
        if cands is None:
            return None
        if not isinstance(cands, list):
            cands = [cands]
        for mesh_axes in cands:
            group = ((mesh_axes,) if isinstance(mesh_axes, str)
                     else tuple(mesh_axes))
            if set(group) & set(used):
                continue
            total = math.prod(self.sizes[a] for a in group)
            if dim % total == 0:
                # normalize 1-tuples to the bare axis name so specs
                # compare equal regardless of how the rule was written
                if (not isinstance(mesh_axes, str)
                        and len(tuple(mesh_axes)) == 1):
                    return tuple(mesh_axes)[0]
                return mesh_axes
        return None  # replicate rather than emit invalid sharding


def pspecs(schema: Tree, rules: Rules) -> Tree:
    def leaf(p: P):
        spec = []
        used = set()
        for dim, ax in zip(p.shape, p.axes):
            r = rules.resolve(ax, dim, used)
            flat = ((r,) if isinstance(r, str) else tuple(r or ()))
            if r is not None:
                used |= set(flat)
            spec.append(r)
        return PartitionSpec(*spec)
    return jax.tree.map(leaf, schema, is_leaf=lambda x: isinstance(x, P))


def make_rules(mesh, *, fsdp: bool = True, seq_parallel: bool = True) -> Rules:
    """Standard rule set for the production meshes (see DESIGN.md §4)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    data_axes = tuple(a for a in ("pod", "data") if a in sizes)
    data = data_axes if len(data_axes) > 1 else data_axes[0]
    table = {
        "embed": data if fsdp else None,
        "vocab": "model",
        "qheads": "model",
        "kvheads": "model",
        "qgroups": "model",
        "act_seq": "model" if seq_parallel else None,
        "mlp": "model",
        "experts": "model",
        "emlp": "model",
        "ssm": "model",
        "batch": data,
        # KV cache sequence axis: long-context decode (batch=1) takes the
        # widest split; otherwise the leftover "model" axis (batch owns
        # the data axes) — flash-decode partial-softmax via GSPMD.
        "kvseq": [tuple(data_axes) + ("model",), ("model",)],
        "layers": None,
    }
    return Rules(table, sizes)


def logical_spec(rules: Rules, *axes, dims=None) -> PartitionSpec:
    """PartitionSpec for an activation with the given logical axes.
    ``dims`` (same length) enables divisibility checks when known."""
    spec = []
    used = set()
    for i, ax in enumerate(axes):
        if ax is None:
            spec.append(None)
            continue
        d = None if dims is None else dims[i]
        if d is not None:
            r = rules.resolve(ax, d, used)
        else:
            r = rules.table.get(ax)
            if isinstance(r, list):
                r = r[0]
            flat = ((r,) if isinstance(r, str) else tuple(r or ()))
            if set(flat) & used:
                r = None
        flat = ((r,) if isinstance(r, str) else tuple(r or ()))
        if r is not None:
            used |= set(flat)
        spec.append(r)
    return PartitionSpec(*spec)
