"""Core layers: norms, embeddings, rotary embedding, gated MLP, logits.

All functions are pure; parameters arrive as dict subtrees produced by
the schemas in :mod:`repro.models.schema`.  Weight matmuls optionally
route through HOBFLOPS-quantized weights (``repro.quant``) — the paper's
custom-precision FP as a first-class serving feature.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.ctx import constrain

from .config import ModelConfig
from .schema import P


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def rmsnorm_schema(d: int):
    return {"scale": P((d,), ("embed",), "ones")}


def rmsnorm(p, x, eps: float):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# Embedding / logits
# ---------------------------------------------------------------------------
def embed_schema(cfg: ModelConfig):
    return {"table": P((cfg.vocab_padded, cfg.d_model), ("vocab", "embed"),
                       "normal", scale=1.0)}


def embed(p, tokens, cfg: ModelConfig):
    return jnp.take(p["table"], tokens, axis=0).astype(cfg.compute_dtype)


def logits_schema(cfg: ModelConfig):
    if cfg.tie_embeddings:
        return {}
    return {"w": P((cfg.d_model, cfg.vocab_padded), ("embed", "vocab"))}


def logits(p, x, cfg: ModelConfig, embed_params=None, deq=None):
    if cfg.tie_embeddings:
        w = embed_params["table"].T
    else:
        w = deq("w", p["w"]) if deq is not None else p["w"]
    lg = jnp.einsum("bsd,dv->bsv", x, w.astype(x.dtype),
                    preferred_element_type=jnp.float32)
    return constrain(lg, "batch", None, "vocab")


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------
def rope_angles(positions, d_head: int, theta: float):
    """positions [...,] int -> (cos, sin) [..., d_head//2] f32."""
    half = d_head // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x [..., S, H, D]; cos/sin broadcastable [..., S, 1, D//2]."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------
def mlp_schema(cfg: ModelConfig, d_ff: int | None = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    return {"w_gate": P((d, f), ("embed", "mlp")),
            "w_up": P((d, f), ("embed", "mlp")),
            "w_down": P((f, d), ("mlp", "embed"))}


def _act(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[name]


def mlp(p, x, cfg: ModelConfig, deq=None):
    """deq: optional weight-dequant hook (name, array) -> array, used by
    the quantized serving path."""
    get = (lambda n: p[n]) if deq is None else (lambda n: deq(n, p[n]))
    h = _act(cfg.mlp_act)(x @ get("w_gate").astype(x.dtype))
    h = constrain(h, "batch", None, "mlp")   # Megatron column-parallel
    h = h * (x @ get("w_up").astype(x.dtype))
    return h @ get("w_down").astype(x.dtype)


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------
def softmax_xent(lg, labels):
    """lg [B,S,V] f32, labels [B,S] int.  Mean token cross-entropy.

    The label pick is a one-hot multiply-reduce, NOT take_along_axis: a
    gather over the model-sharded vocab axis forces GSPMD to replicate
    the full f32 logits (observed: +160 GiB/device on the train cells),
    while the one-hot form fuses into the reduce and partitions as a
    partial-sum + psum over the vocab shards."""
    lg = lg.astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    onehot = jax.nn.one_hot(labels, lg.shape[-1], dtype=lg.dtype)
    picked = jnp.sum(lg * onehot, axis=-1)
    return jnp.mean(lse - picked)
