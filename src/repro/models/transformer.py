"""Model assembly: decoder-only / hybrid / MoE / SSM / enc-dec / VLM.

One code path serves all ten assigned architectures.  The layer stack is
grouped into *super-layers* of ``cfg.scan_period()`` blocks (the smallest
repeating pattern of (attention?, moe?) kinds) and iterated with
``jax.lax.scan`` over parameters stacked along a leading depth axis —
compile time stays flat in depth (llama3's 126 layers lower as one scan
of 63 2-block bodies... actually its period is 1: one scanned block).
Training bodies are rematerialized (``jax.checkpoint``).

Caches (decode) and per-segment KV (prefill) travel through the same
scan as xs/ys trees that mirror the block structure.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from .attention import (attn_schema, attention, decode_attention)
from .config import ModelConfig
from .layers import (embed, embed_schema, logits, logits_schema, mlp,
                     mlp_schema, rmsnorm, rmsnorm_schema, softmax_xent)
from .mamba import (mamba, mamba_decode, mamba_init_state, mamba_schema)
from .moe import moe, moe_schema
from .schema import P, stack

from repro.distributed.ctx import constrain

Tree = Any


# ---------------------------------------------------------------------------
# Schemas
# ---------------------------------------------------------------------------
def block_schema(cfg: ModelConfig, kind: tuple[bool, bool],
                 cross: bool = False) -> Tree:
    is_attn, is_moe = kind
    d = cfg.d_model
    s: dict = {"pre_norm": rmsnorm_schema(d)}
    if is_attn:
        s["attn"] = attn_schema(cfg)
    else:
        s["mamba"] = mamba_schema(cfg)
    if cross:
        s["cross_norm"] = rmsnorm_schema(d)
        s["cross"] = attn_schema(cfg)
    if cfg.d_ff > 0:
        s["mlp_norm"] = rmsnorm_schema(d)
        s["moe" if is_moe else "mlp"] = (
            moe_schema(cfg) if is_moe else mlp_schema(cfg))
    return s


def model_schema(cfg: ModelConfig) -> Tree:
    period = cfg.scan_period()
    depth = cfg.n_layers // period
    kinds = cfg.layer_kinds()[:period]
    cross = cfg.family == "encdec"
    s: dict = {"embed": embed_schema(cfg)}
    s["blocks"] = {f"b{k}": stack(block_schema(cfg, kinds[k], cross), depth)
                   for k in range(period)}
    s["final_norm"] = rmsnorm_schema(cfg.d_model)
    s["logits"] = logits_schema(cfg)
    if cfg.frontend != "none":
        s["frontend"] = {"proj": P((cfg.frontend_dim, cfg.d_model),
                                   (None, "embed"))}
    if cfg.family == "encdec":
        enc_kind = (True, False)
        s["enc_blocks"] = {"e0": stack(block_schema(cfg, enc_kind),
                                       cfg.enc_layers)}
        s["enc_norm"] = rmsnorm_schema(cfg.d_model)
    return s


# ---------------------------------------------------------------------------
# Cross attention (enc-dec): no RoPE, bidirectional over memory.
# ---------------------------------------------------------------------------
def _heads(cfg, q, k, v, B):
    dh = cfg.head_dim
    return (q.reshape(B, -1, cfg.n_heads, dh),
            k.reshape(B, -1, cfg.n_kv_heads, dh),
            v.reshape(B, -1, cfg.n_kv_heads, dh))


def cross_kv(p, memory, cfg: ModelConfig, deq=None):
    get = (lambda n: p[n]) if deq is None else (lambda n: deq(n, p[n]))
    B = memory.shape[0]
    k = memory @ get("wk").astype(memory.dtype)
    v = memory @ get("wv").astype(memory.dtype)
    dh = cfg.head_dim
    return (k.reshape(B, -1, cfg.n_kv_heads, dh),
            v.reshape(B, -1, cfg.n_kv_heads, dh))


def cross_attention(p, x, kv, cfg: ModelConfig, deq=None):
    """x [B,T,d] queries over precomputed memory (k, v)."""
    from .attention import _flash
    get = (lambda n: p[n]) if deq is None else (lambda n: deq(n, p[n]))
    B, T, _ = x.shape
    dh = cfg.head_dim
    q = (x @ get("wq").astype(x.dtype)).reshape(B, T, cfg.n_heads, dh)
    k, v = kv
    o = _flash(q, k, v, causal=False, q_block=512, kv_block=512)
    return o.reshape(B, T, -1) @ get("wo").astype(x.dtype)


# ---------------------------------------------------------------------------
# Block application (one of the `period` positions)
# ---------------------------------------------------------------------------
def apply_block(bp, x, cfg: ModelConfig, kind, *, mode: str,
                cache=None, pos=None, memory=None, causal=True, deq=None):
    """Returns (x, aux, cache_out).  mode: train | prefill | decode.

    cache_out: for prefill, the fresh cache entries for this block (KV of
    the processed segment / final SSM state); for decode, the updated
    cache; for train, None-tree.
    """
    is_attn, is_moe = kind
    aux = jnp.float32(0.0)
    cache_out = {}
    h = rmsnorm(bp["pre_norm"], x, cfg.norm_eps)
    if is_attn:
        if mode == "decode":
            a, new_kv = decode_attention(bp["attn"], h, cfg, cache, pos,
                                         deq=deq)
            cache_out.update(new_kv)
        else:
            a, (k, v) = attention(bp["attn"], h, cfg, causal=causal, deq=deq)
            cache_out.update({"k": k, "v": v})
        x = x + a
    else:
        if mode == "decode":
            m, st = mamba_decode(bp["mamba"], h, cfg,
                                 {"conv": cache["conv"], "ssd": cache["ssd"]},
                                 deq=deq)
        else:
            m, st = mamba(bp["mamba"], h, cfg, deq=deq)
        cache_out.update(st)
        x = x + m
    if "cross" in bp:
        hc = rmsnorm(bp["cross_norm"], x, cfg.norm_eps)
        if mode == "decode":
            kv = (cache["ck"], cache["cv"])   # read-only at decode
        else:
            kv = cross_kv(bp["cross"], memory, cfg, deq=deq)
            cache_out.update({"ck": kv[0], "cv": kv[1]})
        x = x + cross_attention(bp["cross"], hc, kv, cfg, deq=deq)
    if cfg.d_ff > 0:
        h = rmsnorm(bp["mlp_norm"], x, cfg.norm_eps)
        if is_moe:
            y, a = moe(bp["moe"], h, cfg, deq=deq)
            aux = aux + a
        else:
            y = mlp(bp["mlp"], h, cfg, deq=deq)
        x = x + y
    return x, aux, cache_out


# ---------------------------------------------------------------------------
# Stack application via scan over super-layers
# ---------------------------------------------------------------------------
def apply_stack(blocks, x, cfg: ModelConfig, *, mode: str, caches=None,
                pos=None, memory=None, causal=True, deq=None,
                kinds=None, remat=None):
    """blocks: {"b<k>": stacked subtree}; caches mirrors blocks (decode) or
    is None.  Returns (x, aux, caches_out)."""
    period = len(blocks)
    keys = [f"b{k}" for k in range(period)]
    if kinds is None:
        kinds = cfg.layer_kinds()[:period]
    if remat is None:
        remat = mode == "train"

    def body(carry, xs):
        xc, auxc = carry
        # Residual anchor: batch over data, seq over model (Megatron
        # sequence parallelism) when the rules context enables it.
        xc = constrain(xc, "batch", "act_seq", None)
        layer_p = xs[0]
        layer_c = xs[1] if caches is not None else {k: None for k in keys}
        outs = {}
        for i, key in enumerate(keys):
            xc, a, co = apply_block(
                layer_p[key], xc, cfg, kinds[i], mode=mode,
                cache=layer_c[key], pos=pos, memory=memory,
                causal=causal, deq=deq)
            auxc = auxc + a
            outs[key] = co
        return (xc, auxc), outs

    if remat:
        body = jax.checkpoint(body)

    xs = (blocks,) if caches is None else (blocks, caches)
    (x, aux), caches_out = jax.lax.scan(
        body, (x, jnp.float32(0.0)), xs)
    return x, aux, caches_out


# ---------------------------------------------------------------------------
# Embedding front: tokens (+ prefix embeds for VLM)
# ---------------------------------------------------------------------------
def _embed_input(params, batch, cfg: ModelConfig):
    """-> (x [B, S_total, d], n_prefix)."""
    x = embed(params["embed"], batch["tokens"], cfg)
    n_prefix = 0
    if cfg.frontend != "none" and cfg.family != "encdec":
        prefix = batch["prefix"].astype(x.dtype)
        proj = params["frontend"]["proj"].astype(x.dtype)
        x = jnp.concatenate([prefix @ proj, x], axis=1)
        n_prefix = prefix.shape[1]
    return constrain(x, "batch", "act_seq", None), n_prefix


def encode_memory(params, batch, cfg: ModelConfig, remat=False):
    """Enc-dec encoder: frames [B,Se,F] -> memory [B,Se,d]."""
    frames = batch["frames"]
    proj = params["frontend"]["proj"]
    x = frames.astype(cfg.compute_dtype) @ proj.astype(cfg.compute_dtype)
    x, _, _ = apply_stack({"b0": params["enc_blocks"]["e0"]}, x, cfg,
                          mode="train" if remat else "prefill",
                          causal=False, kinds=[(True, False)], remat=remat)
    return rmsnorm(params["enc_norm"], x, cfg.norm_eps)


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------
def forward_logits(params, batch, cfg: ModelConfig, *, mode="train",
                   deq=None):
    """-> (logits [B, S_text, V], aux)."""
    x, n_prefix = _embed_input(params, batch, cfg)
    memory = (encode_memory(params, batch, cfg, remat=(mode == "train"))
              if cfg.family == "encdec" else None)
    x, aux, _ = apply_stack(params["blocks"], x, cfg, mode=mode,
                            memory=memory, deq=deq)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if n_prefix:
        x = x[:, n_prefix:, :]
    lg = logits(params.get("logits", {}), x, cfg,
                embed_params=params["embed"], deq=deq)
    return lg, aux


def lm_loss(params, batch, cfg: ModelConfig, aux_weight: float = 0.01):
    lg, aux = forward_logits(params, batch, cfg, mode="train")
    loss = softmax_xent(lg, batch["labels"])
    total = loss + aux_weight * aux
    return total, {"xent": loss, "aux": aux}


# ---------------------------------------------------------------------------
# Serving: cache init / prefill / decode
# ---------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               enc_len: int = 0, dtype=jnp.bfloat16) -> Tree:
    period = cfg.scan_period()
    depth = cfg.n_layers // period
    kinds = cfg.layer_kinds()[:period]
    dh, hkv = cfg.head_dim, cfg.n_kv_heads

    def one(kind):
        is_attn, _ = kind
        c = {}
        if is_attn:
            c["k"] = jnp.zeros((depth, batch, max_len, hkv, dh), dtype)
            c["v"] = jnp.zeros((depth, batch, max_len, hkv, dh), dtype)
        else:
            st = mamba_init_state(cfg, batch, dtype)
            c["conv"] = jnp.tile(st["conv"][None], (depth, 1, 1, 1))
            c["ssd"] = jnp.tile(st["ssd"][None], (depth, 1, 1, 1, 1))
        if cfg.family == "encdec":
            c["ck"] = jnp.zeros((depth, batch, enc_len, hkv, dh), dtype)
            c["cv"] = jnp.zeros((depth, batch, enc_len, hkv, dh), dtype)
        return c

    return {f"b{k}": one(kinds[k]) for k in range(period)}


def prefill(params, batch, cfg: ModelConfig, max_len: int,
            dtype=jnp.bfloat16, deq=None):
    """Process the prompt; build a max_len cache.  Returns
    (cache, last_logits [B, V], n_prefix)."""
    x, n_prefix = _embed_input(params, batch, cfg)
    memory = (encode_memory(params, batch, cfg)
              if cfg.family == "encdec" else None)
    S = x.shape[1]
    x, aux, fresh = apply_stack(params["blocks"], x, cfg, mode="prefill",
                                memory=memory, deq=deq)
    xl = rmsnorm(params["final_norm"], x[:, -1:, :], cfg.norm_eps)
    lg = logits(params.get("logits", {}), xl, cfg,
                embed_params=params["embed"], deq=deq)[:, 0, :]

    cache = init_cache(cfg, x.shape[0], max_len,
                       enc_len=memory.shape[1] if memory is not None else 0,
                       dtype=dtype)
    merged = {}
    for key, c in cache.items():
        merged[key] = {}
        for name, dst in c.items():
            src = fresh[key][name]
            if name in ("k", "v"):
                merged[key][name] = jax.lax.dynamic_update_slice_in_dim(
                    dst, src.astype(dst.dtype), 0, 2)
            else:
                merged[key][name] = src.astype(dst.dtype)
    return merged, lg, S


def decode_step(params, token, cache, pos, cfg: ModelConfig, deq=None):
    """token [B] int32; pos scalar int32 (current cache length).
    Returns (logits [B, V], new_cache).

    The layer scan reads the KV cache; fresh per-layer (k, v) come back
    stacked and are merged with ONE dynamic-update-slice per cache
    tensor — not one per layer (§Perf iteration 2)."""
    x = embed(params["embed"], token[:, None], cfg)
    x, _, outs = apply_stack(params["blocks"], x, cfg, mode="decode",
                             caches=cache, pos=pos, deq=deq)
    new_cache = {}
    for key, c in cache.items():
        nc = dict(c)
        o = outs[key]
        if "k_new" in o:
            # o["k_new"]: [depth, B, 1, Hkv, D] -> write at seq pos
            for name, src in (("k", o["k_new"]), ("v", o["v_new"])):
                dst = c[name]
                upd = src.astype(dst.dtype)
                start = (0, 0, pos, 0, 0)
                nc[name] = jax.lax.dynamic_update_slice(dst, upd, start)
        if "conv" in o:
            nc["conv"] = o["conv"].astype(c["conv"].dtype)
            nc["ssd"] = o["ssd"]
        new_cache[key] = nc
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    lg = logits(params.get("logits", {}), x, cfg,
                embed_params=params["embed"], deq=deq)[:, 0, :]
    return lg, new_cache
