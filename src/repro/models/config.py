"""Model + run configuration dataclasses.

Every assigned architecture is expressed as a ``ModelConfig``; the four
assigned input shapes are ``ShapeConfig``s.  Configs are pure data — the
model code in this package interprets them.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """HOBFLOPS weight quantization (the paper's technique as a feature).

    format: any name accepted by ``repro.core.fpformat.parse_format``.
    layout: "native" (int8/int16 codes) or "bitplane" (paper's layout,
            exactly nbits bits per weight in HBM).
    targets: which weight families are stored quantized.
    """
    format: str = "hobflops9"
    layout: str = "bitplane"
    targets: tuple[str, ...] = ("mlp", "attn")
    rounding: str = "rne"


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0             # 0 -> d_model // n_heads
    # --- attention flavor ---
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    # --- MLP flavor ---
    mlp_act: str = "silu"       # silu -> SwiGLU, gelu -> GeGLU
    # --- MoE ---
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_layer_period: int = 1   # layer i is MoE iff i % period == offset
    moe_layer_offset: int = 0
    moe_capacity_factor: float = 1.25
    # --- hybrid (Jamba): attention layer placement among SSM layers ---
    attn_layer_period: int = 0  # 0 -> all layers are attention
    attn_layer_offset: int = 0
    # --- SSM (Mamba-1/2 via SSD; mamba1 == headdim 1) ---
    ssm_state: int = 0          # N (d_state); 0 -> no ssm layers
    ssm_headdim: int = 64       # P; 1 reproduces Mamba-1 semantics
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 128
    # --- encoder-decoder ---
    enc_layers: int = 0
    # --- modality frontend stubs ---
    frontend: str = "none"      # none | vit_stub | audio_stub
    num_prefix: int = 0         # patches/frames supplied by the stub
    frontend_dim: int = 0       # embedding dim delivered by the stub
    # --- numerics ---
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # --- technique ---
    quant: Optional[QuantConfig] = None
    # --- activation sharding hints (set by the launcher; None in tests).
    # PartitionSpec args as nested tuples, applied with
    # with_sharding_constraint under the active mesh. ---
    act_pspec: Optional[tuple] = None   # residual stream [B, S, d]
    moe_pspec: Optional[tuple] = None   # MoE dispatch buffer [E, C, d]

    @property
    def head_dim(self) -> int:
        if self.d_head:
            return self.d_head
        return self.d_model // self.n_heads if self.n_heads else 1

    @property
    def vocab_padded(self) -> int:
        """Vocab rounded up to a multiple of 512 so the vocab axis shards
        over any mesh axis used here (16/32); labels are always < vocab."""
        return -(-self.vocab // 512) * 512

    @property
    def ssm_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_inner // self.ssm_headdim

    def is_attn_layer(self, i: int) -> bool:
        if self.family in ("dense", "moe", "vlm", "encdec"):
            return True
        if self.family == "ssm":
            return False
        return (i % self.attn_layer_period) == self.attn_layer_offset

    def is_moe_layer(self, i: int) -> bool:
        if self.moe_experts == 0:
            return False
        return (i % self.moe_layer_period) == self.moe_layer_offset

    def layer_kinds(self) -> list[tuple[bool, bool]]:
        """Per layer (is_attention, is_moe)."""
        return [(self.is_attn_layer(i), self.is_moe_layer(i))
                for i in range(self.n_layers)]

    def scan_period(self) -> int:
        """Smallest layer-period such that the stack is a repetition of
        one period (used to scan over homogeneous super-layers)."""
        kinds = self.layer_kinds()
        for p in range(1, self.n_layers + 1):
            if self.n_layers % p:
                continue
            if all(kinds[i] == kinds[i % p] for i in range(self.n_layers)):
                return p
        return self.n_layers


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """(runs?, reason-if-skip).  long_500k needs sub-quadratic attention."""
    if shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return False, ("pure full-attention arch: 512k dense-attention "
                       "decode is out of scope (DESIGN.md §6)")
    return True, ""
