"""Token-choice top-k MoE with sort-based (MegaBlocks-style) dispatch.

The dispatch avoids the GShard [tokens, experts, capacity] one-hot tensor
(which is infeasible at 1M-token global batches): assignments are sorted
by expert id, ranked within their expert by a cumulative-count subtract,
capacity-dropped, and scattered into a dense [E, C, d] buffer that the
expert FFNs consume as one batched einsum.  Under GSPMD the scatter and
gather lower to the all-to-all pair of a classic expert-parallel MoE
when the `experts` logical axis maps to a mesh axis (olmoe 64e, jamba
16e); when the expert count does not divide the mesh (grok 8e over a
16-way "model" axis) the experts replicate and tensor parallelism falls
back to the per-expert ``emlp`` axis — see schema.py rules.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.ctx import constrain

from .config import ModelConfig
from .layers import _act
from .schema import P


def moe_schema(cfg: ModelConfig, d_ff: int | None = None):
    E, d, f = cfg.moe_experts, cfg.d_model, d_ff or cfg.d_ff
    return {
        "router": P((d, E), ("embed", None)),
        "w_gate": P((E, d, f), ("experts", "embed", "emlp")),
        "w_up": P((E, d, f), ("experts", "embed", "emlp")),
        "w_down": P((E, f, d), ("experts", "emlp", "embed")),
    }


def _capacity(cfg: ModelConfig, T: int) -> int:
    E, k = cfg.moe_experts, cfg.moe_top_k
    c = int(cfg.moe_capacity_factor * T * k / E)
    c = max(c, k, 8)
    return min(-(-c // 8) * 8, T * k)  # pad to 8


def moe(p, x, cfg: ModelConfig, d_ff: int | None = None, deq=None):
    """x [B, S, d] -> (y [B, S, d], aux_loss scalar f32).

    Grouped, GATHER-ONLY dispatch.  Routing/sorting happens per batch
    row (the GShard "group"), every index op carries the batch dim, and
    destination slots are filled by gathers through the sort
    permutation — there is no scatter anywhere.  This matters under
    GSPMD: a scatter-add into a sharded [tokens, d] buffer with
    computed indices was lowered as replicate + mask + all-reduce
    (17 GB of f32 all-reduce per layer per microbatch on the olmoe
    train cell, EXPERIMENTS.md §Perf iteration 7); batched gathers with
    matching batch sharding stay shard-local, and the one remaining
    cross-expert gather (the combine) is the EP all-to-all equivalent.
    """
    get = (lambda n: p[n]) if deq is None else (lambda n: deq(n, p[n]))
    B, S, d = x.shape
    E, k = cfg.moe_experts, cfg.moe_top_k
    C = _capacity(cfg, S)                                   # per group
    A = S * k                                               # assignments

    # Router in f32 (always).
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                 # [B,S,E]
    gate, expert = jax.lax.top_k(probs, k)                  # [B,S,k]
    gate = gate / jnp.maximum(jnp.sum(gate, -1, keepdims=True), 1e-9)

    flat_e = expert.reshape(B, A)
    flat_g = gate.reshape(B, A)
    tok = (jnp.arange(A, dtype=jnp.int32) // k)             # [A]
    order = jnp.argsort(flat_e, axis=-1)                    # [B,A] stable
    st = jnp.take(tok, order)                               # token per pos
    iperm = jnp.argsort(order, axis=-1)                     # inverse perm

    # per-group expert counts / offsets (one-hot fuses into the reduce)
    counts = jnp.sum(jax.nn.one_hot(flat_e, E, dtype=jnp.int32), axis=1)
    offsets = jnp.cumsum(counts, axis=-1) - counts          # [B,E]

    # ---- dispatch by gather: which sorted position fills slot (e, c)?
    src_pos = offsets[:, :, None] + jnp.arange(C, dtype=jnp.int32)
    slot_valid = jnp.arange(C)[None, None, :] < counts[:, :, None]
    src_pos = jnp.clip(src_pos, 0, A - 1).reshape(B, E * C)
    tok_for_slot = jnp.take_along_axis(st, src_pos, axis=-1)  # [B,E*C]
    disp = jnp.take_along_axis(x, tok_for_slot[..., None], axis=1)
    disp = disp * slot_valid.reshape(B, E * C, 1).astype(x.dtype)
    disp = disp.reshape(B, E, C, d)
    # batch over data, experts over model (EP): expert matmuls are
    # fully local per (data, model) shard.
    disp = constrain(disp, "batch", "experts", None, None)

    # ---- expert FFN (batched over B and E) ---------------------------------
    act = _act(cfg.mlp_act)
    wg = get("w_gate").astype(x.dtype)
    wu = get("w_up").astype(x.dtype)
    wd = get("w_down").astype(x.dtype)
    h = act(jnp.einsum("becd,edf->becf", disp, wg))
    h = h * jnp.einsum("becd,edf->becf", disp, wu)
    out_e = jnp.einsum("becf,efd->becd", h, wd)
    # NB: sharding d_model here (hoping for a reduce-scatter epilogue on
    # the non-EP/row-parallel case) was tried and refuted — GSPMD kept
    # the all-reduce and added resharding traffic (§Perf iteration 9).
    out_e = constrain(out_e, "batch", "experts", None, None)

    # ---- combine by gather: slot of each assignment ------------------------
    rank_sorted = (jnp.arange(A, dtype=jnp.int32)[None, :]
                   - jnp.take_along_axis(
                       offsets, jnp.take_along_axis(flat_e, order, -1),
                       axis=-1))                            # [B,A]
    rank_j = jnp.take_along_axis(rank_sorted, iperm, axis=-1)
    keep_j = rank_j < C
    slot_j = flat_e * C + jnp.where(keep_j, rank_j, 0)      # [B,A]
    contrib = jnp.take_along_axis(
        out_e.reshape(B, E * C, d), slot_j[..., None], axis=1)
    w_assign = (flat_g * keep_j).astype(x.dtype)
    y = jnp.sum((contrib * w_assign[..., None]).reshape(B, S, k, d),
                axis=2)
    y = constrain(y, "batch", None, None)

    # Switch-style load-balance aux loss.
    frac_tokens = jnp.mean(
        jax.nn.one_hot(expert[..., 0], E, dtype=jnp.float32),
        axis=(0, 1))
    mean_prob = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(frac_tokens * mean_prob)
    return y, aux


def moe_dense_ref(p, x, cfg: ModelConfig, d_ff: int | None = None):
    """No-drop dense reference: every expert computes every token.  Used
    by tests to bound the dispatch path (equal when nothing is dropped)."""
    B, S, d = x.shape
    T = B * S
    E, k = cfg.moe_experts, cfg.moe_top_k
    xt = x.reshape(T, d)
    logits = xt.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert = jax.lax.top_k(probs, k)
    gate = gate / jnp.maximum(jnp.sum(gate, -1, keepdims=True), 1e-9)
    act = _act(cfg.mlp_act)
    h = act(jnp.einsum("td,edf->etf", xt, p["w_gate"].astype(xt.dtype)))
    h = h * jnp.einsum("td,edf->etf", xt, p["w_up"].astype(xt.dtype))
    out_e = jnp.einsum("etf,efd->etd", h, p["w_down"].astype(xt.dtype))
    mask = jax.nn.one_hot(expert, E, dtype=jnp.float32)       # [T,k,E]
    w = jnp.einsum("tk,tke->et", gate, mask).astype(xt.dtype)
    y = jnp.einsum("etd,et->td", out_e, w)
    return y.reshape(B, S, d)
