"""GQA/MQA attention: flash-style chunked training path + cached decode.

Training/prefill never materializes the [S, S] score matrix: an outer
scan over query blocks and an inner scan over KV blocks carry the
running (max, denominator, accumulator) triple — the standard
memory-roofline-friendly formulation.  Causality is enforced by masking
inside the scan (rectangular iteration; the triangular-dispatch variant
is a §Perf hillclimb, see EXPERIMENTS.md).

Decode attends one query against a pre-allocated KV cache with a length
mask; the cache sequence axis may be sharded (long-context decode) —
GSPMD turns the row-softmax into a partial-softmax + all-reduce
combine, i.e. flash-decode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.ctx import constrain

from .config import ModelConfig
from .layers import apply_rope, rmsnorm, rope_angles
from .schema import P

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Schema
# ---------------------------------------------------------------------------
def attn_schema(cfg: ModelConfig):
    d, hq, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    s = {"wq": P((d, hq * dh), ("embed", "qheads")),
         "wk": P((d, hkv * dh), ("embed", "kvheads")),
         "wv": P((d, hkv * dh), ("embed", "kvheads")),
         "wo": P((hq * dh, d), ("qheads", "embed"))}
    if cfg.qkv_bias:
        s["bq"] = P((hq * dh,), ("qheads",), "zeros")
        s["bk"] = P((hkv * dh,), ("kvheads",), "zeros")
        s["bv"] = P((hkv * dh,), ("kvheads",), "zeros")
    if cfg.qk_norm:
        s["q_norm"] = P((dh,), (None,), "ones")
        s["k_norm"] = P((dh,), (None,), "ones")
    return s


def _project_qkv(p, x, cfg: ModelConfig, positions, deq=None):
    """x [B,T,d] -> q [B,T,Hq,D], k/v [B,T,Hkv,D] (roped, normed)."""
    get = (lambda n: p[n]) if deq is None else (lambda n: deq(n, p[n]))
    B, T, _ = x.shape
    dh = cfg.head_dim
    q = x @ get("wq").astype(x.dtype)
    k = x @ get("wk").astype(x.dtype)
    v = x @ get("wv").astype(x.dtype)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = constrain(q.reshape(B, T, cfg.n_heads, dh),
                  "batch", None, "qheads", None)
    k = constrain(k.reshape(B, T, cfg.n_kv_heads, dh),
                  "batch", None, "kvheads", None)
    v = constrain(v.reshape(B, T, cfg.n_kv_heads, dh),
                  "batch", None, "kvheads", None)
    if cfg.qk_norm:
        q = rmsnorm({"scale": p["q_norm"]}, q, cfg.norm_eps)
        k = rmsnorm({"scale": p["k_norm"]}, k, cfg.norm_eps)
    cos, sin = rope_angles(positions, dh, cfg.rope_theta)
    cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    return apply_rope(q, cos, sin), apply_rope(k, cos, sin), v


# ---------------------------------------------------------------------------
# Flash-style chunked attention (train / prefill)
# ---------------------------------------------------------------------------
def _flash(q, k, v, *, causal: bool, q_block: int, kv_block: int,
           q_offset: int = 0):
    """q [B,S,Hq,D], k/v [B,S,Hkv,D] -> [B,S,Hq,D].  Blockwise softmax.

    Positions are derived from scalar block indices + in-loop iota, NOT
    from precomputed position arrays passed as scan xs: constant array
    xs trigger XLA's loop-invariant sinking, which materializes the
    causal mask for every (q-block, kv-block) pair at once — observed
    as a multi-GiB pred buffer carried through the while loop (see
    EXPERIMENTS.md §Perf, iteration 0)."""
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = D ** -0.5
    Tq, Tk = min(q_block, Sq), min(kv_block, Sk)
    nq, nk = Sq // Tq, Sk // Tk
    assert Sq % Tq == 0 and Sk % Tk == 0

    # Anchor the blocked layouts: batch over data, kv-heads (and the
    # grouped-query dim for GQA) over model.  Without these anchors
    # GSPMD loses the sharding across the 6-D block reshapes and
    # replicates the score tensors.
    qb = constrain(q.reshape(B, nq, Tq, Hkv, G, D).astype(jnp.float32),
                   "batch", None, None, "kvheads", "qgroups", None) * scale
    kb = constrain(k.reshape(B, nk, Tk, Hkv, D).astype(jnp.float32),
                   "batch", None, None, "kvheads", None)
    vb = constrain(v.reshape(B, nk, Tk, Hkv, D).astype(jnp.float32),
                   "batch", None, None, "kvheads", None)
    iota_q = jax.lax.iota(jnp.int32, Tq)
    iota_k = jax.lax.iota(jnp.int32, Tk)

    def q_step(_, qi):
        qcur, qidx = qi                     # [B,Tq,Hkv,G,D], scalar
        qp = qidx * Tq + iota_q + q_offset  # [Tq]
        m0 = constrain(jnp.full((B, Tq, Hkv, G), NEG_INF, jnp.float32),
                       "batch", None, "kvheads", "qgroups")
        l0 = constrain(jnp.zeros((B, Tq, Hkv, G), jnp.float32),
                       "batch", None, "kvheads", "qgroups")
        a0 = constrain(jnp.zeros((B, Tq, Hkv, G, D), jnp.float32),
                       "batch", None, "kvheads", "qgroups", None)

        def kv_step(carry, ki):
            m, l, acc = carry
            kcur, vcur, kidx = ki           # [B,Tk,Hkv,D], ..., scalar
            s = jnp.einsum("btkgd,bukd->btkgu", qcur, kcur)
            if causal:
                kp = kidx * Tk + iota_k     # [Tk]
                bias = jnp.where(qp[:, None] >= kp[None, :],
                                 0.0, NEG_INF).astype(jnp.float32)
                s = s + bias[None, :, None, None, :]
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = (acc * corr[..., None]
                       + jnp.einsum("btkgu,bukd->btkgd", p, vcur))
            return (m_new, l_new, acc_new), None

        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0),
             jax.lax.iota(jnp.int32, nk)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out

    _, ob = jax.lax.scan(q_step, None,
                         (jnp.moveaxis(qb, 1, 0),
                          jax.lax.iota(jnp.int32, nq)))
    out = jnp.moveaxis(ob, 0, 1).reshape(B, Sq, Hq, D)
    return out.astype(q.dtype)


def attention(p, x, cfg: ModelConfig, *, causal: bool = True,
              q_block: int = 512, kv_block: int = 512, deq=None,
              kv_override=None):
    """Full attention over x (train/prefill).  Returns (out, (k, v)).

    kv_override: (k, v) from an encoder (cross-attention); x only makes
    queries then.
    """
    B, T, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(T), (B, T))
    q, k, v = _project_qkv(p, x, cfg, positions, deq)
    if kv_override is not None:
        k, v = kv_override
        causal = False
    o = _flash(q, k, v, causal=causal, q_block=q_block, kv_block=kv_block)
    get = (lambda n: p[n]) if deq is None else (lambda n: deq(n, p[n]))
    out = o.reshape(B, T, -1) @ get("wo").astype(x.dtype)
    return out, (k, v)


# ---------------------------------------------------------------------------
# Cached decode
# ---------------------------------------------------------------------------
def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, n_layers: int,
                  dtype=jnp.bfloat16):
    dh, hkv = cfg.head_dim, cfg.n_kv_heads
    shape = (n_layers, batch, max_len, hkv, dh)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def decode_attention(p, x, cfg: ModelConfig, layer_cache, pos, deq=None,
                     kv_override=None):
    """One-token decode over a READ-ONLY cache.

    x [B,1,d]; layer_cache {k,v}: [B,Smax,Hkv,D] holding tokens
    0..pos-1; the current token attends to the cache plus an explicit
    self term, and the fresh (k_new, v_new) are returned for a single
    post-scan cache merge.  Writing the cache inside the layer scan is
    what the first profile showed to be catastrophic: a dynamic-update-
    slice at a data-dependent index on the sequence-SHARDED dim lowers
    to a whole-buffer select per layer (full per-chip cache read+write
    x n_layers, EXPERIMENTS.md §Perf iteration 2).
    Returns (out [B,1,d], {"k_new","v_new"} [B,1,Hkv,D])."""
    B = x.shape[0]
    positions = jnp.broadcast_to(pos[None, None], (B, 1))
    q, k_new, v_new = _project_qkv(p, x, cfg, positions, deq)

    Hq, Hkv, D = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    G = Hq // Hkv
    qf = q.reshape(B, Hkv, G, D).astype(jnp.float32) * D ** -0.5

    if kv_override is None:
        k, v = layer_cache["k"], layer_cache["v"]
        valid = jnp.arange(k.shape[1]) < pos               # old tokens
        cache_out = {"k_new": k_new, "v_new": v_new}
    else:
        k, v = kv_override
        valid = jnp.ones((k.shape[1],), bool)
        cache_out = {}
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    # Scores keep the cache's sequence sharding: the softmax over a
    # "kvseq"-sharded axis lowers to partial softmax + combine
    # collectives (flash-decode) under GSPMD.  The q-group dim is
    # deliberately NOT sharded here: decode is memory-bound on the
    # cache, and giving "model" to qgroups instead of kvseq made GSPMD
    # all-gather the full cache every layer (§Perf iteration 4).
    s = constrain(jnp.einsum("bkgd,bskd->bkgs", qf, kf),
                  "batch", "kvheads", None, "kvseq")
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    if kv_override is None:
        # Two-part flash-decode combine.  NOT a concat: concatenating
        # the self term onto the kvseq-SHARDED score axis makes GSPMD
        # all-gather the scores (and with them V) every layer (§Perf
        # iteration 5).  Reductions over the sharded axis lower to
        # partials + a tiny combine instead.
        s_self = jnp.einsum("bkgd,bukd->bkgu", qf,
                            k_new.astype(jnp.float32))   # [B,Hkv,G,1]
        m = jnp.maximum(jnp.max(s, axis=-1, keepdims=True), s_self)
        pw = jnp.exp(s - m)
        p_self = jnp.exp(s_self - m)                     # [B,Hkv,G,1]
        denom = jnp.sum(pw, axis=-1, keepdims=True) + p_self
        o = (jnp.einsum("bkgs,bskd->bkgd", pw, vf)
             + p_self * v_new.astype(jnp.float32)[:, 0][:, :, None])
        o = o / denom
    else:
        o = jnp.einsum("bkgs,bskd->bkgd", jax.nn.softmax(s, axis=-1),
                       vf)
    o = o.reshape(B, 1, Hq * D)
    get = (lambda n: p[n]) if deq is None else (lambda n: deq(n, p[n]))
    out = o.astype(x.dtype) @ get("wo").astype(x.dtype)
    return out, cache_out
