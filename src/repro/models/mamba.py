"""Mamba-2 (SSD, state-space duality) mixer: chunked train/prefill scan
plus O(1)-per-token recurrent decode.

The chunked algorithm is the quadratic-within-chunk / linear-across-chunk
decomposition of arXiv:2405.21060 §6: intra-chunk outputs come from a
masked (C Bᵀ ∘ L) X einsum that maps onto the MXU, inter-chunk state is
carried by a short ``lax.scan`` over chunks.  All decays run in f32
(exp of non-positive numbers — stable by construction).

Logical shapes: d_inner = expand * d_model, H = d_inner / headdim P,
state N = cfg.ssm_state, single B/C group (n_groups = 1).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.ctx import constrain

from .config import ModelConfig
from .layers import rmsnorm
from .schema import P


def mamba_schema(cfg: ModelConfig):
    d, di, N, H = cfg.d_model, cfg.ssm_inner, cfg.ssm_state, cfg.ssm_heads
    conv_dim = di + 2 * N
    d_in = 2 * di + 2 * N + H
    return {
        "in_proj": P((d, d_in), ("embed", "ssm")),
        "conv_w": P((cfg.ssm_conv, conv_dim), (None, "ssm"), "normal",
                    scale=0.5),
        "conv_b": P((conv_dim,), ("ssm",), "zeros"),
        "A_log": P((H,), (None,), "zeros"),      # A = -exp(A_log) = -1 init
        "dt_bias": P((H,), (None,), "zeros"),
        "D": P((H,), (None,), "ones"),
        "norm": P((di,), ("ssm",), "ones"),
        "out_proj": P((di, d), ("ssm", "embed")),
    }


def _segsum(x):
    """x [..., Q] -> [..., Q, Q]; out[..., i, j] = sum_{j<k<=i} x_k for
    i >= j, -inf above the diagonal (log-space decay matrix L)."""
    Q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(xdt, dA, Bm, Cm, chunk: int, h0=None):
    """Chunked SSD scan.

    xdt [B,S,H,P] f32 (inputs pre-multiplied by dt), dA [B,S,H] f32
    (dt * A, <= 0), Bm/Cm [B,S,N] f32.  Returns (y [B,S,H,P] f32,
    h_final [B,H,P,N] f32).  S % chunk == 0.
    """
    B, S, H, Pd = xdt.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    if S % Q:
        # Zero-pad to a chunk multiple: xdt=0 injects nothing, dA=0 means
        # decay exp(0)=1, so the final state is exact; padded outputs are
        # sliced off below.
        pad = Q - S % Q
        xdt = jnp.pad(xdt, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dA = jnp.pad(dA, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        y, h = ssd_chunked(xdt, dA, Bm, Cm, chunk, h0)
        return y[:, :S], h
    nc = S // Q

    # Heads over "model" inside SSD (the chunk axis stays local: the
    # inter-chunk recurrence is sequential).
    xc = constrain(xdt.reshape(B, nc, Q, H, Pd),
                   "batch", None, None, "ssm", None)
    dAc = constrain(dA.reshape(B, nc, Q, H), "batch", None, None, "ssm")
    Bc = Bm.reshape(B, nc, Q, N)
    Cc = Cm.reshape(B, nc, Q, N)

    cs = jnp.cumsum(dAc, axis=2)                       # [B,nc,Q,H]
    # Intra-chunk (the "quadratic attention-like" branch).
    L = jnp.exp(_segsum(jnp.moveaxis(dAc, -1, -2)))    # [B,nc,H,Q,Q]
    scores = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc)     # [B,nc,Q,Q]
    y_diag = jnp.einsum("bcqk,bchqk,bckhp->bcqhp", scores, L, xc)

    # Per-chunk end states.
    decay_states = jnp.exp(cs[:, :, -1:, :] - cs)      # [B,nc,Q,H]
    states = jnp.einsum("bcqn,bcqh,bcqhp->bchpn", Bc, decay_states, xc)

    # Inter-chunk recurrence.
    chunk_decay = jnp.exp(cs[:, :, -1, :])             # [B,nc,H]
    if h0 is None:
        h0 = jnp.zeros((B, H, Pd, N), jnp.float32)

    def step(h, inp):
        st, dec = inp                                  # [B,H,P,N], [B,H]
        h_prev = h
        h = h * dec[:, :, None, None] + st
        return h, h_prev

    h_final, h_prevs = jax.lax.scan(
        step, h0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)              # [B,nc,H,P,N]

    # Contribution of carried-in state to each position.
    state_decay = jnp.exp(cs)                          # [B,nc,Q,H]
    y_off = jnp.einsum("bcqn,bchpn,bcqh->bcqhp", Cc, h_prevs, state_decay)

    y = (y_diag + y_off).reshape(B, S, H, Pd)
    return y, h_final


def _causal_conv(xBC, w, b):
    """Depthwise causal conv1d.  xBC [B,S,D], w [K,D], b [D]."""
    K = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    S = xBC.shape[1]
    out = sum(pad[:, k:k + S, :] * w[k][None, None, :] for k in range(K))
    return out + b[None, None, :]


def mamba_init_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    di, N, H, Pd = (cfg.ssm_inner, cfg.ssm_state, cfg.ssm_heads,
                    cfg.ssm_headdim)
    conv_dim = di + 2 * N
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
        "ssd": jnp.zeros((batch, H, Pd, N), jnp.float32),
    }


def _split_proj(cfg: ModelConfig, zxbcdt):
    di, N, H = cfg.ssm_inner, cfg.ssm_state, cfg.ssm_heads
    conv_dim = di + 2 * N
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di:di + conv_dim]
    dt = zxbcdt[..., di + conv_dim:]
    assert dt.shape[-1] == H
    return z, xBC, dt


def _ssd_inputs(cfg: ModelConfig, p, xBC, dt):
    """Post-conv xBC + raw dt -> f32 (x [.., H, P], dA, Bm, Cm, dt_sp)."""
    di, N, H, Pd = (cfg.ssm_inner, cfg.ssm_state, cfg.ssm_heads,
                    cfg.ssm_headdim)
    x = xBC[..., :di].astype(jnp.float32)
    Bm = xBC[..., di:di + N].astype(jnp.float32)
    Cm = xBC[..., di + N:].astype(jnp.float32)
    dt_sp = jax.nn.softplus(dt.astype(jnp.float32)
                            + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))       # [H]
    dA = dt_sp * A                                     # [..., H]
    x = x.reshape(*x.shape[:-1], H, Pd)
    return x, dA, Bm, Cm, dt_sp


def mamba(p, x, cfg: ModelConfig, deq=None, h0=None):
    """Full-sequence mixer.  x [B,S,d] -> (y [B,S,d], final_state)."""
    get = (lambda n: p[n]) if deq is None else (lambda n: deq(n, p[n]))
    B, S, d = x.shape
    zxbcdt = constrain(x @ get("in_proj").astype(x.dtype),
                       "batch", None, "ssm")
    z, xBC_pre, dt = _split_proj(cfg, zxbcdt)
    xBC = jax.nn.silu(_causal_conv(xBC_pre, p["conv_w"].astype(x.dtype),
                                   p["conv_b"].astype(x.dtype)))
    xs, dA, Bm, Cm, dt_sp = _ssd_inputs(cfg, p, xBC, dt)
    xdt = xs * dt_sp[..., None]
    ssd0 = None if h0 is None else h0["ssd"]
    y, h_final = ssd_chunked(xdt, dA, Bm, Cm, cfg.ssm_chunk, ssd0)
    y = y + p["D"].astype(jnp.float32)[None, None, :, None] * xs
    y = y.reshape(B, S, cfg.ssm_inner).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm({"scale": p["norm"]}, y, cfg.norm_eps)
    out = y @ get("out_proj").astype(x.dtype)
    # The decode conv window needs the last K-1 PRE-activation xBC rows.
    K = cfg.ssm_conv
    conv_state = xBC_pre[:, -(K - 1):, :] if S >= K - 1 else jnp.pad(
        xBC_pre, ((0, 0), (K - 1 - S, 0), (0, 0)))
    state = {"conv": conv_state, "ssd": h_final}
    return out, state


def mamba_decode(p, x, cfg: ModelConfig, state, deq=None):
    """One-token recurrent step.  x [B,1,d] -> (y [B,1,d], new_state)."""
    get = (lambda n: p[n]) if deq is None else (lambda n: deq(n, p[n]))
    B = x.shape[0]
    di, N, H, Pd = (cfg.ssm_inner, cfg.ssm_state, cfg.ssm_heads,
                    cfg.ssm_headdim)
    zxbcdt = x[:, 0, :] @ get("in_proj").astype(x.dtype)   # [B, d_in]
    z, xBC_new, dt = _split_proj(cfg, zxbcdt)

    # conv: window = [state | new]; state holds the previous K-1 pre-act
    window = jnp.concatenate([state["conv"], xBC_new[:, None, :]], axis=1)
    w = p["conv_w"].astype(x.dtype)                        # [K, D]
    xBC = jnp.einsum("bkd,kd->bd", window, w) + p["conv_b"].astype(x.dtype)
    xBC = jax.nn.silu(xBC)
    new_conv = window[:, 1:, :]

    xs, dA, Bm, Cm, dt_sp = _ssd_inputs(cfg, p, xBC, dt)   # x [B,H,P]
    h = state["ssd"]                                       # [B,H,P,N]
    dec = jnp.exp(dA)                                      # [B,H]
    inj = jnp.einsum("bhp,bn->bhpn", xs * dt_sp[..., None], Bm)
    h = h * dec[:, :, None, None] + inj
    y = jnp.einsum("bn,bhpn->bhp", Cm, h)
    y = y + p["D"].astype(jnp.float32)[None, :, None] * xs
    y = y.reshape(B, di).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm({"scale": p["norm"]}, y, cfg.norm_eps)
    out = (y @ get("out_proj").astype(x.dtype))[:, None, :]
    return out, {"conv": new_conv, "ssd": h}


# ---------------------------------------------------------------------------
# Oracle for tests: naive per-step recurrence in f64-ish (f32) numpy space.
# ---------------------------------------------------------------------------
def ssd_reference(xdt, dA, Bm, Cm, h0=None):
    """Sequential SSD recurrence (oracle).  Same signature as ssd_chunked
    minus chunking."""
    import numpy as np
    xdt, dA, Bm, Cm = (np.asarray(a, np.float64) for a in (xdt, dA, Bm, Cm))
    B, S, H, Pd = xdt.shape
    N = Bm.shape[-1]
    h = (np.zeros((B, H, Pd, N)) if h0 is None
         else np.asarray(h0, np.float64))
    ys = np.zeros((B, S, H, Pd))
    for t in range(S):
        dec = np.exp(dA[:, t])                         # [B,H]
        h = h * dec[:, :, None, None] + np.einsum(
            "bhp,bn->bhpn", xdt[:, t], Bm[:, t])
        ys[:, t] = np.einsum("bn,bhpn->bhp", Cm[:, t], h)
    return ys, h
