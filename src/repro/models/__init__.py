"""Model substrate: configs, schemas, layers and full-model assembly."""
from .config import ModelConfig, QuantConfig, ShapeConfig, SHAPES
from .transformer import (decode_step, forward_logits, init_cache, lm_loss,
                          model_schema, prefill)

__all__ = ["ModelConfig", "QuantConfig", "ShapeConfig", "SHAPES",
           "model_schema", "forward_logits", "lm_loss", "prefill",
           "decode_step", "init_cache"]
