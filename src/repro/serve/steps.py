"""Serving steps: prefill and single-token decode, with optional
HOBFLOPS-quantized weights (the paper's custom-precision FP as the
memory-bandwidth lever of decode).

Decode is the memory-roofline-bound phase: every step reads all weights
plus the KV cache once.  With ``quant`` enabled, targeted weight
families are held in HOBFLOPS bitplane codes (exactly nbits bits per
weight in HBM) and dequantized on the fly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import decode_step, prefill
from repro.models.config import ModelConfig


def make_prefill_step(cfg: ModelConfig, max_len: int, deq=None):
    def prefill_step(params, batch):
        cache, last_logits, length = prefill(params, batch, cfg, max_len,
                                             deq=deq)
        return cache, last_logits, length
    return prefill_step


def make_decode_step(cfg: ModelConfig, deq=None, sample: str = "greedy"):
    def serve_step(params, token, pos, cache):
        logits, new_cache = decode_step(params, token, cache, pos, cfg,
                                        deq=deq)
        if sample == "greedy":
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            raise ValueError(sample)
        return nxt, logits, new_cache
    return serve_step
