"""Batched serving engine (wave-scheduled continuous batching).

Production decode runs a fixed-size batch of *slots* in lockstep so one
compiled decode step serves every request mix.  This engine schedules
in waves: up to ``n_slots`` queued requests are admitted together,
prompts are padded to the wave's common prefill length, the wave
decodes in lockstep, requests that finish early are masked out (their
slots keep decoding garbage that is simply discarded — the standard
price of lockstep batching), and the next wave starts when the wave
drains.  All positions stay synchronized, which keeps the decode step's
single-position cache semantics exact.

Per-slot ragged admission (true token-level continuous batching) needs
vector positions in the decode path — per-slot validity masks and a
scatter merge; noted in DESIGN.md as the next serving feature.

Works with quantized (HOBFLOPS bitplane) weights via the same ``deq``
hook as everything else.
"""
from __future__ import annotations

import dataclasses
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode_step, prefill
from repro.models.config import ModelConfig


@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray            # prompt token ids [S]
    max_new: int = 16
    eos_id: int | None = None
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, n_slots: int = 4,
                 max_len: int = 256, deq=None, cache_dtype=jnp.float32):
        assert cfg.family != "encdec", \
            "engine currently serves decoder-only families"
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.deq = deq
        self.queue: deque[Request] = deque()
        self.total_decode_steps = 0
        self.total_tokens = 0
        self._decode = jax.jit(
            lambda p, t, pos, c: decode_step(p, t, c, pos, cfg, deq=deq))
        self._prefill = jax.jit(
            lambda p, batch: prefill(p, batch, cfg, max_len,
                                     dtype=cache_dtype, deq=deq))

    def submit(self, req: Request):
        self.queue.append(req)

    # ---- one wave -----------------------------------------------------------
    def _run_wave(self) -> list[Request]:
        wave = [self.queue.popleft()
                for _ in range(min(self.n_slots, len(self.queue)))]
        B = self.n_slots
        plen = max(len(r.tokens) for r in wave)
        toks = np.zeros((B, plen), np.int32)
        for i, r in enumerate(wave):
            # left-pad by repeating the first token: every position is a
            # real token so the causal mask stays trivially valid, and
            # generation conditions on the full prompt suffix.
            pad = plen - len(r.tokens)
            toks[i, :pad] = r.tokens[0]
            toks[i, pad:] = r.tokens
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.frontend != "none":
            batch["prefix"] = jnp.zeros(
                (B, self.cfg.num_prefix, self.cfg.frontend_dim),
                jnp.float32)

        cache, logits, length = self._prefill(self.params, batch)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        live = []
        for i, r in enumerate(wave):
            r.out.append(int(tok[i]))
            live.append(not (len(r.out) >= r.max_new
                             or (r.eos_id is not None
                                 and r.out[-1] == r.eos_id)))
        pos = int(length)

        budget = max(r.max_new for r in wave) - 1
        for _ in range(budget):
            if pos >= self.max_len - 1 or not any(live):
                break
            logits, cache = self._decode(
                self.params, tok, jnp.asarray(pos, jnp.int32), cache)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            nxt = np.asarray(tok)
            self.total_decode_steps += 1
            for i, r in enumerate(wave):
                if not live[i]:
                    continue
                r.out.append(int(nxt[i]))
                self.total_tokens += 1
                if (len(r.out) >= r.max_new
                        or (r.eos_id is not None
                            and r.out[-1] == r.eos_id)):
                    live[i] = False
            pos += 1
        for r in wave:
            r.done = True
        return wave

    def run(self) -> list[Request]:
        finished: list[Request] = []
        while self.queue:
            finished.extend(self._run_wave())
        return finished
