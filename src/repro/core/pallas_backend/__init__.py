"""Fused Pallas netlist compiler backend (DESIGN.md §12).

Lowers a whole optimized netlist — the K-step MAC chain plus its
round/relu epilogue — into a *single* Pallas kernel body: the
``_slot_schedule`` register allocation becomes an explicit in-kernel
register file of lane-word temporaries, every gate becomes one
straight-line vector bitwise op, and bus I/O maps onto the kernel's
block-specced refs so the launch tiles through the existing
``tune_conv_blocks`` machinery.

Selected as ``backend="pallas_fused"`` in ``hobflops_matmul`` /
``conv_core`` / ``NetworkGraph`` / ``ConvServeEngine``; bit-identical
to the gate-interpreter backends and the softfloat oracle.
"""
from .emitter import (STACK_MAX_DEFAULT, LoweredNetlist,
                      RegisterFileOverflow, lower_netlist)
from .kernel import fused_chain_lowered, fused_mac_pallas, fused_chain_k

__all__ = [
    "STACK_MAX_DEFAULT", "LoweredNetlist", "RegisterFileOverflow",
    "lower_netlist", "fused_chain_lowered", "fused_mac_pallas",
    "fused_chain_k",
]
