"""The fused MAC-chain Pallas kernel.

One ``pl.pallas_call`` carries the whole layer: the K-step MAC chain
netlist (lowered by :mod:`.emitter` into a straight-line register-file
program), the channel reduction as an in-kernel ``fori_loop`` over
ref slices, and the ReLU epilogue as two in-kernel ops on the final
C-step — so a fused conv emits exactly one kernel in its jaxpr where
the gate-interpreter backends emit hundreds of elementwise HLO ops.

Grid/BlockSpec layout matches ``bitslice_mac_pallas`` (DESIGN.md §5):
the C reduction is the innermost grid axis with output-block
revisiting, P and M tile through ``tune_conv_blocks``'s block knobs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.fpcore import build_mac_chain
from repro.core.fpformat import RNE, FPFormat
from repro.core.opt import optimize_mapped

from .emitter import STACK_MAX_DEFAULT, LoweredNetlist, lower_netlist


@functools.lru_cache(maxsize=None)
def fused_chain_lowered(fmt: FPFormat, k: int, extended: bool,
                        rounding: str, lib: str = "tpu_vpu",
                        stack_max: int = STACK_MAX_DEFAULT
                        ) -> LoweredNetlist:
    """The optimized ``lib``-mapped K-step MAC chain, lowered once per
    (format, chain depth, rounding, policy) to a fused kernel body."""
    mapped = optimize_mapped(build_mac_chain(fmt, k, extended, rounding),
                             lib)
    return lower_netlist(mapped, stack_max=stack_max)


def fused_chain_k(fmt: FPFormat, extended: bool = False,
                  requested: int = 4,
                  stack_max: int = STACK_MAX_DEFAULT) -> int:
    """Chain depth the fused backend actually uses.

    Wide-accumulator formats (out bus past ``stack_max``, e.g.
    hobflops16's 19 planes) keep ``k=1``: their chain bodies grow the
    XLA compile time superlinearly (minutes at k=4) while the one-hot
    bus assembly already removes the cone-duplication that chaining
    would otherwise amortize.  Narrow formats keep the requested depth.
    """
    nout = fmt.mult_out(extended).nbits
    return 1 if nout > stack_max else max(1, requested)


def _fused_mac_kernel(i_ref, w_ref, o_ref, *, c_block: int,
                      c_unroll: int, nout: int, n_c: int, sign_off: int,
                      relu: bool, fmt: FPFormat, extended: bool,
                      rounding: str, stack_max: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        # +0.0 in FloPoCo encoding is the all-zero code word.
        o_ref[...] = jnp.zeros_like(o_ref)

    lowered = fused_chain_lowered(fmt, c_unroll, extended, rounding,
                                  stack_max=stack_max)
    acc_shape = o_ref.shape            # (NOUT, P_blk, Mt)
    assert acc_shape[0] == nout, (acc_shape, nout)
    assert c_block % c_unroll == 0, (c_block, c_unroll)

    def step(s, acc):
        base = s * c_unroll
        xw = w_ref[pl.ds(base, c_unroll)]        # [c_unroll, NIN, Mt]
        yb = i_ref[:, pl.ds(base, c_unroll), :]  # [P_blk, c_unroll, NIN]
        kwargs = {"acc": acc}
        for j in range(c_unroll):
            kwargs[f"x{j}"] = xw[j][:, None, :]              # [NIN,1,Mt]
            kwargs[f"y{j}"] = jnp.transpose(yb[:, j, :],
                                            (1, 0))[:, :, None]
        out = lowered(**kwargs)["out"]
        return jnp.broadcast_to(out, acc_shape)

    o_ref[...] = jax.lax.fori_loop(0, c_block // c_unroll, step,
                                   o_ref[...])

    if relu:
        # In-kernel epilogue, only once the C reduction is complete:
        # clear every plane where the sign plane is set (the
        # hobflops_relu_planes semantics, DESIGN.md §8).
        @pl.when(ci == n_c - 1)
        def _epilogue():
            acc = o_ref[...]
            o_ref[...] = acc & ~acc[sign_off][None]


def fused_mac_pallas(i_masks, w_planes, *, fmt: FPFormat,
                     extended: bool = False, rounding: str = RNE,
                     p_block: int = 8, m_block: int = 128,
                     c_block: int = 64, c_unroll: int = 4,
                     relu: bool = False, interpret: bool = False,
                     stack_max: int = STACK_MAX_DEFAULT):
    """Launch the fused MAC-chain kernel.

    Same contract as ``bitslice_mac_pallas`` (i_masks [P, C, NIN] in
    {0, -1}, w_planes [C, NIN, Mw], returns OFM planes [NOUT, P, Mw])
    plus the fused ReLU epilogue; ``c_unroll`` is additionally clamped
    through :func:`fused_chain_k`.  Bit-identical to the interpreter
    backends for every format x rounding (tests pin this), and the
    whole layer is one ``pallas_call``.
    """
    P, C, nin = i_masks.shape
    C2, nin2, Mw = w_planes.shape
    assert (C, nin) == (C2, nin2), (i_masks.shape, w_planes.shape)
    assert nin == fmt.nbits
    fmt_out = fmt.mult_out(extended)
    nout = fmt_out.nbits
    p_block = min(p_block, P)
    m_block = min(m_block, Mw)
    c_block = min(c_block, C)
    assert P % p_block == 0 and Mw % m_block == 0 and C % c_block == 0
    c_unroll = fused_chain_k(fmt, extended,
                             max(1, min(c_unroll, c_block)), stack_max)
    while c_block % c_unroll:
        c_unroll -= 1

    n_c = C // c_block
    grid = (P // p_block, Mw // m_block, n_c)
    kernel = functools.partial(
        _fused_mac_kernel, c_block=c_block, c_unroll=c_unroll,
        nout=nout, n_c=n_c, sign_off=fmt_out.sign_off, relu=relu,
        fmt=fmt, extended=extended, rounding=rounding,
        stack_max=stack_max)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((p_block, c_block, nin),
                         lambda pi, mi, ci: (pi, ci, 0)),
            pl.BlockSpec((c_block, nin, m_block),
                         lambda pi, mi, ci: (ci, 0, mi)),
        ],
        out_specs=pl.BlockSpec((nout, p_block, m_block),
                               lambda pi, mi, ci: (0, pi, mi)),
        out_shape=jax.ShapeDtypeStruct((nout, P, Mw), jnp.int32),
        interpret=interpret,
    )(i_masks, w_planes)
