"""Netlist -> fused-kernel-body emitter.

``lower_netlist`` turns an optimized :class:`~repro.core.circuit.Graph`
into a :class:`LoweredNetlist`: a callable whose trace is the *body* of
a fused kernel.  Three things distinguish it from the plain
``make_jax_fn`` gate interpreter (DESIGN.md §12):

* **Register file.**  The ``_slot_schedule`` register allocation is
  realized as a fixed-size file of lane-word temporaries.  The file
  size is pinned at lowering time; a netlist whose peak live-slot count
  exceeds an explicitly requested file raises
  :class:`RegisterFileOverflow` *before anything executes* — the
  backend fails loudly rather than spilling silently or corrupting
  lanes.

* **Straight-line gates.**  Each gate is exactly the cell's vector
  bitwise form (MUX as the 3-op ``b ^ (s & (a ^ b))``, LUT3 as its
  minterm expansion) with operands read from register-file slots — the
  software mirror of the paper's topologically-sorted generated C.

* **Bus assembly policy.**  How the output planes leave the kernel is
  *the* performance decision on the XLA CPU backend, which has no
  multi-output fusion and caps per-instruction indexing-path
  duplication at ~15 (``FusionNodeIndexingEvaluation``).  A bus
  assembled with a ``concatenate`` of more operands than the cap makes
  XLA split every output cone into its own fusion, recomputing the
  shared netlist interior per cone (measured 17x duplication and a
  ~6 MMAC/s hobflops16).  Policy: buses at or under ``stack_max``
  planes use the plain stack (one fusion, zero redundancy — the
  hobflops8/9 fast path); wider buses are assembled by an or-tree of
  one-hot-masked broadcasts — pure same-shape elementwise ops with a
  single fusion root, trading ~50% arithmetic overhead for the removal
  of the 17x duplication (measured 3x end-to-end on hobflops16).
"""
from __future__ import annotations

import weakref

from repro.core.circuit import (FALSE, OP_AND, OP_ANDN, OP_INPUT, OP_LUT3,
                                OP_MUX, OP_NOT, OP_OR, OP_XOR, TRUE, Graph)
from repro.core.codegen import _slot_schedule

# XLA CPU's FusionNodeIndexingEvaluation refuses fusions once a shared
# instruction accumulates ~15 distinct indexing paths; a concatenate
# contributes one path per operand, so buses stay under this.
STACK_MAX_DEFAULT = 14


class RegisterFileOverflow(RuntimeError):
    """The netlist needs more live lane-word temporaries than the
    requested register file holds.  Raised at lowering time — the fused
    backend never spills and never truncates the file silently."""

    def __init__(self, need: int, have: int):
        self.need = need
        self.have = have
        super().__init__(
            f"netlist needs {need} register-file slots but the file "
            f"holds {have}; enlarge the file (or leave regfile_size "
            f"unset to size it from the schedule)")


def _assemble_bus(descs, env, zeros, ones, stack_max: int):
    """Assemble one output bus from register-file slots.

    ``descs`` are the ``("slot", s)`` / ``("const", 0|1)`` wire
    descriptors of ``_slot_schedule``; returns a stacked
    ``[width, ...lanes]`` plane array built per the policy above.
    """
    import jax.numpy as jnp

    planes = [env[s] if kind == "slot" else (ones if s else zeros)
              for kind, s in descs]
    shape = jnp.broadcast_shapes(*(getattr(p, "shape", ())
                                   for p in planes))
    n = len(descs)
    if n <= stack_max:
        return jnp.stack([jnp.broadcast_to(p, shape) for p in planes])

    # One-hot masked or-tree: every term is the full [n, ...lanes]
    # shape with exactly one live row, so the whole assembly is
    # same-shape elementwise ops under a single fusion root.  Constant
    # rows fold into one template term.  The masks are built from an
    # in-trace iota (not closed-over arrays): Pallas kernel bodies may
    # not capture non-scalar constants, and XLA constant-folds the
    # iota/compare chain to the same mask either way.
    import jax

    rows = jax.lax.broadcasted_iota(jnp.int32,
                                    (n,) + (1,) * len(shape), 0)

    def onehot(r):
        return -(rows == r).astype(jnp.int32)        # 0 / -1 row mask

    terms = []
    tmpl = None
    for r, ((kind, s), p) in enumerate(zip(descs, planes)):
        if kind == "const":
            if s:
                tmpl = onehot(r) if tmpl is None else tmpl | onehot(r)
            continue
        terms.append(jnp.broadcast_to(p, (n,) + shape) & onehot(r))
    if tmpl is not None:
        terms.append(jnp.broadcast_to(tmpl, (n,) + shape))
    if not terms:
        return jnp.broadcast_to(jnp.zeros((), jnp.int32), (n,) + shape)
    while len(terms) > 1:
        terms = [terms[i] | terms[i + 1]
                 for i in range(0, len(terms) - 1, 2)] + \
            ([terms[-1]] if len(terms) % 2 else [])
    return terms[0]


class LoweredNetlist:
    """A netlist lowered to a fused kernel body.

    Calling it with ``**{bus: planes}`` traces the straight-line gate
    program over the register file and returns assembled output plane
    arrays per bus — bit-identical to ``eval_netlist`` /
    ``make_jax_fn`` on the same graph (the assembly policy changes the
    XLA fusion shape, never the values).
    """

    def __init__(self, graph: Graph, steps, nslots: int, out_wires,
                 regfile_size: int, stack_max: int):
        self.graph = graph
        self.steps = steps
        self.nslots = nslots
        self.out_wires = out_wires
        self.regfile_size = regfile_size
        self.stack_max = stack_max

    def __call__(self, **inputs):
        import jax.numpy as jnp

        sample = next(iter(inputs.values()))
        zeros = jnp.zeros_like(sample[0])
        ones = ~zeros
        nodes = self.graph.nodes
        regs: list = [None] * self.regfile_size   # the register file

        def rd(slot, child):
            if slot >= 0:
                return regs[slot]
            return ones if child == TRUE else zeros

        for nid, slot, cs, free_after in self.steps:
            n = nodes[nid]
            if n.op == OP_INPUT:
                name, bit = n.aux
                v = inputs[name][bit]
            elif n.op == OP_NOT:
                v = ~rd(cs[0], n.a)
            elif n.op == OP_AND:
                v = rd(cs[0], n.a) & rd(cs[1], n.b)
            elif n.op == OP_OR:
                v = rd(cs[0], n.a) | rd(cs[1], n.b)
            elif n.op == OP_XOR:
                v = rd(cs[0], n.a) ^ rd(cs[1], n.b)
            elif n.op == OP_ANDN:
                v = rd(cs[0], n.a) & ~rd(cs[1], n.b)
            elif n.op == OP_MUX:
                s, a, b = rd(cs[0], n.a), rd(cs[1], n.b), rd(cs[2], n.c)
                v = b ^ (s & (a ^ b))
            elif n.op == OP_LUT3:
                a, b, c = rd(cs[0], n.a), rd(cs[1], n.b), rd(cs[2], n.c)
                tt = n.aux
                v = zeros
                for m in range(8):
                    if (tt >> m) & 1:
                        t = (a if m & 1 else ~a)
                        t = t & (b if m & 2 else ~b)
                        t = t & (c if m & 4 else ~c)
                        v = v | t
            else:  # pragma: no cover
                raise ValueError(f"bad op {n.op}")
            for f in free_after:
                regs[f] = None
            regs[slot] = v
        return {name: _assemble_bus(descs, regs, zeros, ones,
                                    self.stack_max)
                for name, descs in self.out_wires.items()}


# One lowering per (graph, file size, policy) — repeated kernel traces
# of the same netlist reuse the schedule instead of re-allocating.
_LOWER_CACHE: "weakref.WeakKeyDictionary[Graph, dict]" = \
    weakref.WeakKeyDictionary()


def lower_netlist(graph: Graph, *, regfile_size: int | None = None,
                  stack_max: int = STACK_MAX_DEFAULT) -> LoweredNetlist:
    """Lower ``graph`` to a fused kernel body.

    ``regfile_size`` pins the register file; ``None`` sizes it from the
    schedule's peak live-slot count.  An explicit size smaller than the
    peak raises :class:`RegisterFileOverflow` immediately.
    ``stack_max`` is the bus-assembly policy threshold (see module
    docstring); values are unaffected, only XLA fusion shape.
    """
    per_graph = _LOWER_CACHE.setdefault(graph, {})
    key = (regfile_size, stack_max)
    cached = per_graph.get(key)
    if cached is not None:
        return cached
    steps, nslots, out_wires = _slot_schedule(graph)
    size = nslots if regfile_size is None else regfile_size
    if nslots > size:
        raise RegisterFileOverflow(nslots, size)
    lowered = LoweredNetlist(graph, steps, nslots, out_wires, size,
                             stack_max)
    per_graph[key] = lowered
    return lowered
