"""Combinational datapath building blocks over the circuit IR.

These are the structures FloPoCo would emit as VHDL: ripple-carry adders,
barrel shifters with sticky collection, leading-zero counters, array
multipliers, comparators.  All buses are lists of node ids, LSB first.
"""
from __future__ import annotations

from .circuit import FALSE, TRUE, Graph


def const_bus(g: Graph, value: int, width: int) -> list[int]:
    return [TRUE if (value >> i) & 1 else FALSE for i in range(width)]


def bus_value_known(bus: list[int]) -> int | None:
    """If every wire is constant, return the integer value, else None."""
    v = 0
    for i, w in enumerate(bus):
        if w == TRUE:
            v |= 1 << i
        elif w != FALSE:
            return None
    return v


def full_adder(g: Graph, a: int, b: int, cin: int) -> tuple[int, int]:
    """Returns (sum, carry).  The classic 5-gate form; hash-consing will
    share the a^b term between sum and carry (paper Listing 1)."""
    axb = g.XOR(a, b)
    s = g.XOR(axb, cin)
    # carry = (a & b) | (cin & (a ^ b))
    cout = g.OR(g.AND(a, b), g.AND(cin, axb))
    return s, cout


def ripple_add(g: Graph, a: list[int], b: list[int], cin: int = FALSE,
               width: int | None = None) -> tuple[list[int], int]:
    """a + b (+cin) over `width` bits (default max input width).
    Returns (sum_bus, carry_out)."""
    if width is None:
        width = max(len(a), len(b))
    out = []
    c = cin
    for i in range(width):
        ai = a[i] if i < len(a) else FALSE
        bi = b[i] if i < len(b) else FALSE
        s, c = full_adder(g, ai, bi, c)
        out.append(s)
    return out, c


def negate(g: Graph, a: list[int]) -> list[int]:
    inv = [g.NOT(x) for x in a]
    s, _ = ripple_add(g, inv, const_bus(g, 0, len(a)), cin=TRUE)
    return s


def ripple_sub(g: Graph, a: list[int], b: list[int],
               width: int | None = None) -> tuple[list[int], int]:
    """a - b.  Returns (diff, borrow_out) where borrow_out=1 iff a < b
    (unsigned)."""
    if width is None:
        width = max(len(a), len(b))
    binv = [g.NOT(b[i]) if i < len(b) else TRUE for i in range(width)]
    diff, carry = ripple_add(g, a, binv, cin=TRUE, width=width)
    return diff, g.NOT(carry)


def increment(g: Graph, a: list[int], en: int = TRUE) -> tuple[list[int], int]:
    """a + en. Returns (sum, carry_out). Half-adder chain."""
    out = []
    c = en
    for x in a:
        out.append(g.XOR(x, c))
        c = g.AND(x, c)
    return out, c


def eq_zero(g: Graph, a: list[int]) -> int:
    r = TRUE
    for x in a:
        r = g.AND(r, g.NOT(x))
    return r


def bus_eq(g: Graph, a: list[int], b: list[int]) -> int:
    assert len(a) == len(b)
    r = TRUE
    for x, y in zip(a, b):
        r = g.AND(r, g.XNOR(x, y))
    return r


def ult(g: Graph, a: list[int], b: list[int]) -> int:
    """Unsigned a < b via subtract borrow."""
    _, borrow = ripple_sub(g, a, b)
    return borrow


def ucmp(g: Graph, a: list[int], b: list[int]) -> tuple[int, int]:
    """Unsigned (a < b, a > b) from one subtract chain + an equality
    reduce — cheaper than two independent :func:`ult` subtracts when a
    comparator needs both directions (the FP max swap logic)."""
    lt = ult(g, a, b)
    n = max(len(a), len(b))
    eq = TRUE
    for i in range(n):
        ai = a[i] if i < len(a) else FALSE
        bi = b[i] if i < len(b) else FALSE
        eq = g.AND(eq, g.XNOR(ai, bi))
    return lt, g.AND(g.NOT(lt), g.NOT(eq))


def mux_bus(g: Graph, s: int, a: list[int], b: list[int]) -> list[int]:
    """s ? a : b, element-wise (buses padded with FALSE)."""
    n = max(len(a), len(b))
    out = []
    for i in range(n):
        ai = a[i] if i < len(a) else FALSE
        bi = b[i] if i < len(b) else FALSE
        out.append(g.MUX(s, ai, bi))
    return out


def shr_barrel(g: Graph, a: list[int], shamt: list[int],
               collect_sticky: bool = False) -> tuple[list[int], int]:
    """Logical right shift of `a` by the unsigned value of `shamt`.

    Shift amounts >= len(a) shift everything out.  If collect_sticky,
    also returns the OR of all bits shifted out (FP alignment sticky).
    """
    cur = list(a)
    sticky = FALSE
    for k, sbit in enumerate(shamt):
        dist = 1 << k
        if dist >= len(cur):
            # shifting by this power empties the bus entirely
            if collect_sticky:
                any_bit = FALSE
                for x in cur:
                    any_bit = g.OR(any_bit, x)
                sticky = g.OR(sticky, g.AND(sbit, any_bit))
            cur = [g.MUX(sbit, FALSE, x) for x in cur]
            continue
        if collect_sticky:
            lost = FALSE
            for x in cur[:dist]:
                lost = g.OR(lost, x)
            sticky = g.OR(sticky, g.AND(sbit, lost))
        nxt = []
        for i in range(len(cur)):
            hi = cur[i + dist] if i + dist < len(cur) else FALSE
            nxt.append(g.MUX(sbit, hi, cur[i]))
        cur = nxt
    return cur, sticky


def shl_barrel(g: Graph, a: list[int], shamt: list[int]) -> list[int]:
    """Logical left shift (bits shifted past MSB are dropped)."""
    cur = list(a)
    for k, sbit in enumerate(shamt):
        dist = 1 << k
        nxt = []
        for i in range(len(cur)):
            lo = cur[i - dist] if i - dist >= 0 else FALSE
            nxt.append(g.MUX(sbit, lo, cur[i]))
        cur = nxt
    return cur


def normalize_shift(g: Graph, a: list[int]) -> tuple[list[int], list[int]]:
    """Fused leading-zero count + left shift (a 'normalizer').

    Returns (shifted, count) where `shifted` has the leading one of `a`
    at the MSB position and `count` is the shift amount (== lzc when a
    is nonzero).  Cheaper than lzc + shl_barrel because the zero-check of
    each stage feeds its own mux row directly (what Genus would do to
    the FloPoCo normalization cone).
    """
    n = len(a)
    stages = max(1, (n - 1).bit_length())
    cur = list(a)
    count: list[int] = []
    for k in reversed(range(stages)):
        dist = 1 << k
        # top `dist` bits all zero?
        top = cur[n - dist:]
        allz = TRUE
        for x in top:
            allz = g.AND(allz, g.NOT(x))
        if dist >= n:
            count.append(FALSE)
            continue
        nxt = []
        for i in range(n):
            lo = cur[i - dist] if i - dist >= 0 else FALSE
            nxt.append(g.MUX(allz, lo, cur[i]))
        cur = nxt
        count.append(allz)
    count.reverse()  # LSB first
    return cur, count


def lzc(g: Graph, a: list[int]) -> list[int]:
    """Leading-zero count of `a` (MSB = a[-1]).  Output width is
    ceil(log2(len(a)+1)).  If a == 0 the count saturates at len(a)."""
    n = len(a)
    width = max(1, (n).bit_length())
    # Priority encode from MSB down: count = index of first 1 from top.
    count = const_bus(g, n, width)  # all-zero case
    for i in range(n):  # i = 0 is LSB; scan from LSB up so MSB wins last
        cnt_here = const_bus(g, n - 1 - i, width)
        count = mux_bus(g, a[i], cnt_here, count)
    return count


def mul_unsigned(g: Graph, a: list[int], b: list[int]) -> list[int]:
    """Array multiplier; result width len(a)+len(b)."""
    n, m = len(a), len(b)
    acc: list[int] = [FALSE] * (n + m)
    for j in range(m):
        pp = [g.AND(a[i], b[j]) for i in range(n)]
        # accumulate pp << j into acc[j : j+n+1]
        seg = acc[j:j + n]
        summed, carry = ripple_add(g, seg, pp)
        acc[j:j + n] = summed
        # propagate carry upward
        k = j + n
        while carry != FALSE and k < n + m:
            s = g.XOR(acc[k], carry)
            carry = g.AND(acc[k], carry)
            acc[k] = s
            k += 1
    return acc


def or_reduce(g: Graph, bus: list[int]) -> int:
    r = FALSE
    for x in bus:
        r = g.OR(r, x)
    return r


def and_reduce(g: Graph, bus: list[int]) -> int:
    r = TRUE
    for x in bus:
        r = g.AND(r, x)
    return r
