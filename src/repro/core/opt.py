"""Netlist optimization + technology mapping (the Genus/ABC analogue).

The paper feeds FloPoCo VHDL through Cadence Genus + Yosys/ABC with
custom Liberty cell libraries matching each ISA's bitwise instructions
(Table 1).  Here the same role is played by a priority-cuts, area-flow
technology mapper over the circuit IR:

* ``LIB_AVX2``   — 2-input AND/OR/XOR/ANDN + NOT (x86 SIMD bitwise ops)
* ``LIB_NEON``   — 2-input AND/OR/XOR/ORN + NOT + 3-input SEL (mux)
* ``LIB_AVX512`` — every 3-input boolean function (ternary-LUT imm8)
* ``LIB_TPU_VPU``— 2-input AND/OR/XOR + NOT: what XLA exposes as single
                   elementwise HLO bitwise ops on the TPU vector unit.
                   (TPUs have no ternary bitwise instruction; the paper's
                   AVX512 trick does not transfer — see DESIGN.md.)

Mapping is semantics-preserving; tests re-verify mapped netlists against
the originals (the analogue of the paper's Yosys SAT check).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable

from .circuit import (FALSE, OP_AND, OP_ANDN, OP_CONST, OP_INPUT, OP_LUT3,
                      OP_MUX, OP_NOT, OP_OR, OP_XOR, TRUE, Graph)

_MAX_CUTS = 10  # priority cuts kept per node


def _tt_for(nvars: int, var: int) -> int:
    """Truth table (2^nvars bits) of projection onto variable `var`."""
    pat = 0
    for m in range(1 << nvars):
        if (m >> var) & 1:
            pat |= 1 << m
    return pat


def _mask(nvars: int) -> int:
    return (1 << (1 << nvars)) - 1


@dataclasses.dataclass(frozen=True)
class CellLib:
    name: str
    k: int                                   # max cut size
    tts: dict[tuple[int, int], str]          # (nvars, tt) -> cell name

    def supports(self, nvars: int, tt: int) -> str | None:
        return self.tts.get((nvars, tt))


def _two_input_tts(cells: dict[str, Callable[[int, int], int]]):
    """Build (nvars=2, tt) table from python bitwise lambdas over a,b."""
    out: dict[tuple[int, int], str] = {}
    a, b = _tt_for(2, 0), _tt_for(2, 1)
    m = _mask(2)
    for name, fn in cells.items():
        out[(2, fn(a, b) & m)] = name
        out.setdefault((2, fn(b, a) & m), name)  # commuted operand order
    return out


def _base_tts() -> dict[tuple[int, int], str]:
    tts = _two_input_tts({
        "AND2": lambda a, b: a & b,
        "OR2": lambda a, b: a | b,
        "XOR2": lambda a, b: a ^ b,
    })
    tts[(1, 0b01)] = "NOT"
    return tts


def make_lib_avx2() -> CellLib:
    tts = _base_tts()
    tts.update(_two_input_tts({"ANDN2": lambda a, b: a & ~b}))
    return CellLib("avx2", 2, tts)


def make_lib_tpu() -> CellLib:
    return CellLib("tpu_vpu", 2, _base_tts())


def make_lib_neon() -> CellLib:
    tts = _base_tts()
    tts.update(_two_input_tts({"ORN2": lambda a, b: a | ~b}))
    # SEL: s ? a : b over every assignment of the 3 cut leaves.
    s_, a_, b_ = (_tt_for(3, i) for i in range(3))
    m = _mask(3)
    for perm in itertools.permutations((0, 1, 2)):
        vs = [_tt_for(3, p) for p in perm]
        tt = ((vs[0] & vs[1]) | (~vs[0] & vs[2])) & m
        tts.setdefault((3, tt), "SEL")
    return CellLib("neon", 3, tts)


def make_lib_avx512() -> CellLib:
    tts = _base_tts()
    for tt in range(256):
        tts.setdefault((3, tt), f"LUT{tt:03d}")
    # 2-input ternary ops are also single vpternlog instructions
    for tt in range(16):
        tts.setdefault((2, tt), f"LUT2_{tt:02d}")
    return CellLib("avx512", 3, tts)


CELL_LIBS: dict[str, Callable[[], CellLib]] = {
    "avx2": make_lib_avx2,
    "neon": make_lib_neon,
    "avx512": make_lib_avx512,
    "tpu_vpu": make_lib_tpu,
}


# ---------------------------------------------------------------------------
# MUX / LUT3 decomposition (pre-pass so every node is 1-2 input)
# ---------------------------------------------------------------------------
def decompose(graph: Graph) -> Graph:
    """Rewrite MUX/LUT3/ANDN into {NOT, AND, OR, XOR} form."""
    g2 = Graph()
    remap: dict[int, int] = {FALSE: FALSE, TRUE: TRUE}
    for nid in graph.topo_order():
        n = graph.nodes[nid]
        if nid in (FALSE, TRUE):
            continue
        if n.op == OP_INPUT:
            name, bit = n.aux
            if name not in g2.inputs:
                g2.input_bus(name, len(graph.inputs[name]))
            remap[nid] = g2.inputs[name][bit]
        elif n.op == OP_NOT:
            remap[nid] = g2.NOT(remap[n.a])
        elif n.op == OP_AND:
            remap[nid] = g2.AND(remap[n.a], remap[n.b])
        elif n.op == OP_OR:
            remap[nid] = g2.OR(remap[n.a], remap[n.b])
        elif n.op == OP_XOR:
            remap[nid] = g2.XOR(remap[n.a], remap[n.b])
        elif n.op == OP_ANDN:
            remap[nid] = g2.AND(remap[n.a], g2.NOT(remap[n.b]))
        elif n.op == OP_MUX:
            # 3-gate form: b ^ (s & (a ^ b)) — optimal for 2-input libs,
            # and 3-cut recovery still re-derives SEL/LUT3 from it.
            s, a, b = remap[n.a], remap[n.b], remap[n.c]
            remap[nid] = g2.XOR(b, g2.AND(s, g2.XOR(a, b)))
        elif n.op == OP_LUT3:
            a, b, c = remap[n.a], remap[n.b], remap[n.c]
            acc = FALSE
            for m in range(8):
                if (n.aux >> m) & 1:
                    t = a if m & 1 else g2.NOT(a)
                    t = g2.AND(t, b if m & 2 else g2.NOT(b))
                    t = g2.AND(t, c if m & 4 else g2.NOT(c))
                    acc = g2.OR(acc, t)
            remap[nid] = acc
        else:  # pragma: no cover
            raise ValueError(n.op)
    # make sure unreferenced input buses survive
    for name, bus in graph.inputs.items():
        if name not in g2.inputs:
            g2.input_bus(name, len(bus))
    for name, bus in graph.outputs.items():
        g2.output_bus(name, [remap[w] for w in bus])
    return g2


# ---------------------------------------------------------------------------
# Priority-cuts area-flow mapper
# ---------------------------------------------------------------------------
def _cut_tt(graph: Graph, node: int, cut: tuple[int, ...]) -> int:
    """Truth table of `node` as a function of the cut leaves."""
    nvars = len(cut)
    assign = {leaf: _tt_for(nvars, i) for i, leaf in enumerate(cut)}
    m = _mask(nvars)
    memo: dict[int, int] = dict(assign)
    memo[FALSE] = 0
    memo[TRUE] = m

    def ev(x: int) -> int:
        v = memo.get(x)
        if v is not None:
            return v
        n = graph.nodes[x]
        if n.op == OP_NOT:
            v = ~ev(n.a) & m
        elif n.op == OP_AND:
            v = ev(n.a) & ev(n.b)
        elif n.op == OP_OR:
            v = ev(n.a) | ev(n.b)
        elif n.op == OP_XOR:
            v = ev(n.a) ^ ev(n.b)
        else:  # pragma: no cover
            raise ValueError(f"unmapped-op {n.op} reached tt eval")
        memo[x] = v
        return v

    return ev(node)


def tech_map(graph: Graph, lib: CellLib) -> Graph:
    """Map onto `lib`, minimizing mapped cell count (area flow heuristic)."""
    g = decompose(graph)
    order = g.topo_order()
    nodes = g.nodes

    fanout: dict[int, int] = {}
    for nid in order:
        n = nodes[nid]
        for ch in (n.a, n.b):
            if ch >= 0:
                fanout[ch] = fanout.get(ch, 0) + 1

    is_leaf = {nid for nid in order
               if nodes[nid].op in (OP_INPUT, OP_CONST)}

    cuts: dict[int, list[tuple[int, ...]]] = {}
    best: dict[int, tuple[tuple[int, ...], float]] = {}  # node -> (cut, flow)

    def flow_of(cut: tuple[int, ...]) -> float:
        f = 1.0
        for leaf in cut:
            if leaf in is_leaf:
                continue
            f += best[leaf][1] / max(1, fanout.get(leaf, 1))
        return f

    for nid in order:
        if nid in is_leaf or nid in (FALSE, TRUE):
            cuts[nid] = [(nid,)]
            continue
        n = nodes[nid]
        children = [c for c in (n.a, n.b) if c >= 0]
        cand: set[tuple[int, ...]] = set()
        if len(children) == 1:
            for c1 in cuts[children[0]]:
                if len(c1) <= lib.k:
                    cand.add(tuple(sorted(c1)))
        else:
            for c1 in cuts[children[0]]:
                for c2 in cuts[children[1]]:
                    u = tuple(sorted(set(c1) | set(c2)))
                    if len(u) <= lib.k:
                        cand.add(u)
        # score every cut; only library-implementable ones are choosable,
        # but all survive enumeration so parents can build larger cuts.
        scored, choosable = [], []
        for cut in cand:
            tt = _cut_tt(g, nid, cut)
            fl = flow_of(cut)
            scored.append((fl, cut))
            if lib.supports(len(cut), tt) is not None:
                choosable.append((fl, cut))
        if not choosable:
            # trivial cut fallback: direct children, native op cost 1
            cut = tuple(sorted(children))
            choosable = [(flow_of(cut), cut)]
        choosable.sort(key=lambda t: (t[0], len(t[1])))
        best[nid] = (choosable[0][1], choosable[0][0])
        scored.sort(key=lambda t: (t[0], len(t[1])))
        keep = [c for _, c in scored[:_MAX_CUTS]]
        cuts[nid] = keep + [(nid,)]

    # ---- cover extraction -------------------------------------------------
    g2 = Graph()
    new_id: dict[int, int] = {FALSE: FALSE, TRUE: TRUE}
    for name, bus in g.inputs.items():
        nb = g2.input_bus(name, len(bus))
        for old, new in zip(bus, nb):
            new_id[old] = new

    def emit(nid: int) -> int:
        if nid in new_id:
            return new_id[nid]
        n = nodes[nid]
        cut, _ = best[nid]
        tt = _cut_tt(g, nid, cut)
        cell = lib.supports(len(cut), tt)
        leaves = [emit(leaf) for leaf in cut]
        if cell is None:
            # native-op fallback over direct children
            kids = [emit(c) for c in (n.a, n.b) if c >= 0]
            out = {OP_NOT: lambda: g2.NOT(kids[0]),
                   OP_AND: lambda: g2.AND(*kids),
                   OP_OR: lambda: g2.OR(*kids),
                   OP_XOR: lambda: g2.XOR(*kids)}[n.op]()
        else:
            out = _emit_cell(g2, cell, tt, leaves)
        new_id[nid] = out
        return out

    for name, bus in g.outputs.items():
        g2.output_bus(name, [emit(w) for w in bus])
    return g2


def _emit_cell(g2: Graph, cell: str, tt: int, leaves: list[int]) -> int:
    la = leaves + [FALSE] * (3 - len(leaves))
    if cell == "NOT":
        return g2.NOT(leaves[0])
    if cell == "AND2":
        return _emit2(g2, tt, la, lambda a, b: g2.AND(a, b),
                      lambda a, b: a & b)
    if cell == "OR2":
        return _emit2(g2, tt, la, lambda a, b: g2.OR(a, b),
                      lambda a, b: a | b)
    if cell == "XOR2":
        return _emit2(g2, tt, la, lambda a, b: g2.XOR(a, b),
                      lambda a, b: a ^ b)
    if cell == "ANDN2":
        return _emit2(g2, tt, la, lambda a, b: g2.ANDN(a, b),
                      lambda a, b: a & ~b)
    if cell == "ORN2":
        # a | ~b  ==  NOT(ANDN(b, a)); represent as OR(a, NOT b) which the
        # evaluator costs as one cell via the ORN histogram rewrite... keep
        # it simple and canonical: emit OR(a, NOT(b)) — counted as ORN by
        # the histogram pass below.
        return _emit2(g2, tt, la, lambda a, b: g2.OR(a, g2.NOT(b)),
                      lambda a, b: a | (~b & _mask(2)))
    if cell == "SEL":
        # find the permutation realizing tt as mux(s, a, b)
        for perm in itertools.permutations(range(3)):
            vs = [_tt_for(3, p) for p in perm]
            m = _mask(3)
            if ((vs[0] & vs[1]) | (~vs[0] & vs[2])) & m == tt:
                return g2.MUX(la[perm[0]], la[perm[1]], la[perm[2]])
        raise AssertionError("SEL tt not realizable")
    if cell.startswith("LUT2_"):
        # 2-input ternary LUT: widen tt(2 vars) to tt(3 vars) ignoring c
        tt3 = 0
        for m in range(8):
            if (tt >> (m & 3)) & 1:
                tt3 |= 1 << m
        return g2.LUT3(tt3, la[0], la[1], la[2])
    if cell.startswith("LUT"):
        return g2.LUT3(tt, la[0], la[1], la[2])
    raise AssertionError(cell)


def _emit2(g2, tt, leaves, build, fn):
    m = _mask(2)
    a, b = _tt_for(2, 0), _tt_for(2, 1)
    if fn(a, b) & m == tt:
        return build(leaves[0], leaves[1])
    return build(leaves[1], leaves[0])


# ---------------------------------------------------------------------------
# Post-mapping netlist optimization passes (the ABC clean-up analogue)
# ---------------------------------------------------------------------------
def _lut3_fold(g2: Graph, tt: int, ins: list[int]) -> int:
    """Emit a 3-input function, specializing constants / duplicate /
    complementary inputs down to 2-input gates where possible."""
    # Reduce: substitute constants and merge duplicate/complement inputs.
    live: list[int] = []        # distinct non-constant inputs, in order
    pol: list[tuple[int, int]] = []  # per original var: (live index, invert)
    for w in ins:
        if w == FALSE or w == TRUE:
            pol.append((-1, 1 if w == TRUE else 0))
            continue
        hit = None
        for j, u in enumerate(live):
            if u == w:
                hit = (j, 0)
                break
            if g2._is_compl(u, w):
                hit = (j, 1)
                break
        if hit is None:
            live.append(w)
            hit = (len(live) - 1, 0)
        pol.append(hit)
    nv = len(live)
    # Re-express tt over the live variables.
    tt2 = 0
    for m in range(1 << nv):
        idx = 0
        for i, (j, inv) in enumerate(pol):
            bit = inv if j < 0 else ((m >> j) & 1) ^ inv
            idx |= bit << i
        if (tt >> idx) & 1:
            tt2 |= 1 << m
    if nv == 0:
        return TRUE if tt2 & 1 else FALSE
    if nv == 1:
        u = live[0]
        return {0b00: FALSE, 0b01: g2.NOT(u), 0b10: u, 0b11: TRUE}[tt2 & 3]
    if nv == 2:
        return _emit_tt2(g2, tt2 & 0xF, live[0], live[1])
    if tt2 == 0:
        return FALSE
    if tt2 == 0xFF:
        return TRUE
    return g2.LUT3(tt2, live[0], live[1], live[2])


def _emit_tt2(g2: Graph, tt2: int, u: int, v: int) -> int:
    """Any 2-variable function as <=2 two-input gates (bit m = f(v,u)
    at index (v<<1)|u)."""
    table = {
        0b0000: lambda: FALSE,          0b1111: lambda: TRUE,
        0b1010: lambda: u,              0b1100: lambda: v,
        0b0101: lambda: g2.NOT(u),      0b0011: lambda: g2.NOT(v),
        0b1000: lambda: g2.AND(u, v),   0b1110: lambda: g2.OR(u, v),
        0b0110: lambda: g2.XOR(u, v),   0b1001: lambda: g2.XNOR(u, v),
        0b0111: lambda: g2.NAND(u, v),  0b0001: lambda: g2.NOR(u, v),
        0b0010: lambda: g2.ANDN(u, v),  0b0100: lambda: g2.ANDN(v, u),
        0b1011: lambda: g2.OR(u, g2.NOT(v)),
        0b1101: lambda: g2.OR(v, g2.NOT(u)),
    }
    return table[tt2]()


def _rebuild(graph: Graph, andn_fanout1: set[int] | None = None) -> Graph:
    """Rebuild the live cone through the folding constructors.

    This is simultaneously a constant-propagation pass (the constructors
    fold constant / duplicate / complementary operands; LUT3 truth
    tables are specialized explicitly) and a dead-node sweep (only the
    output cone is visited).  When ``andn_fanout1`` is given, AND nodes
    whose NOT-child id is in the set are re-emitted as fused ANDN cells.
    """
    g2 = Graph()
    remap: dict[int, int] = {FALSE: FALSE, TRUE: TRUE}
    for nid in graph.topo_order():
        n = graph.nodes[nid]
        if nid in (FALSE, TRUE):
            continue
        if n.op == OP_INPUT:
            name, bit = n.aux
            if name not in g2.inputs:
                g2.input_bus(name, len(graph.inputs[name]))
            remap[nid] = g2.inputs[name][bit]
        elif n.op == OP_CONST:
            remap[nid] = TRUE if n.aux else FALSE
        elif n.op == OP_NOT:
            remap[nid] = g2.NOT(remap[n.a])
        elif n.op == OP_AND:
            done = False
            if andn_fanout1:
                for x, y in ((n.a, n.b), (n.b, n.a)):
                    if y in andn_fanout1 and graph.nodes[y].op == OP_NOT:
                        remap[nid] = g2.ANDN(remap[x],
                                             remap[graph.nodes[y].a])
                        done = True
                        break
            if not done:
                remap[nid] = g2.AND(remap[n.a], remap[n.b])
        elif n.op == OP_OR:
            remap[nid] = g2.OR(remap[n.a], remap[n.b])
        elif n.op == OP_XOR:
            remap[nid] = g2.XOR(remap[n.a], remap[n.b])
        elif n.op == OP_ANDN:
            remap[nid] = g2.ANDN(remap[n.a], remap[n.b])
        elif n.op == OP_MUX:
            remap[nid] = g2.MUX(remap[n.a], remap[n.b], remap[n.c])
        elif n.op == OP_LUT3:
            remap[nid] = _lut3_fold(
                g2, n.aux, [remap[n.a], remap[n.b], remap[n.c]])
        else:  # pragma: no cover
            raise ValueError(n.op)
    for name, bus in graph.inputs.items():
        if name not in g2.inputs:
            g2.input_bus(name, len(bus))
    for name, bus in graph.outputs.items():
        g2.output_bus(name, [remap[w] for w in bus])
    return g2


def const_prop(graph: Graph) -> Graph:
    """Propagate constants / local identities through every gate (also
    sweeps dead nodes; LUT3 cells with degenerate inputs shrink)."""
    return _rebuild(graph)


def sweep(graph: Graph) -> Graph:
    """Drop nodes not reachable from any output (dead-node sweep).

    Structure-preserving: live nodes are copied verbatim (no folding —
    use :func:`const_prop` for that), so mapped cell choices survive."""
    g2 = Graph()
    remap: dict[int, int] = {FALSE: FALSE, TRUE: TRUE}
    for nid in graph.topo_order():
        n = graph.nodes[nid]
        if nid in (FALSE, TRUE):
            continue
        if n.op == OP_INPUT:
            name, _ = n.aux
            if name not in g2.inputs:
                g2.input_bus(name, len(graph.inputs[name]))
            remap[nid] = g2.inputs[name][n.aux[1]]
        elif n.op == OP_CONST:
            remap[nid] = TRUE if n.aux else FALSE
        else:
            remap[nid] = g2._new(
                n.op, remap.get(n.a, -1) if n.a >= 0 else -1,
                remap.get(n.b, -1) if n.b >= 0 else -1,
                remap.get(n.c, -1) if n.c >= 0 else -1, n.aux)
    for name, bus in graph.inputs.items():
        if name not in g2.inputs:
            g2.input_bus(name, len(bus))
    for name, bus in graph.outputs.items():
        g2.output_bus(name, [remap[w] for w in bus])
    return g2


def absorb_andn(graph: Graph) -> Graph:
    """Fuse AND(a, NOT b) -> ANDN(a, b) wherever the NOT has no other
    fanout.  Only valid for libraries with an ANDN cell (avx2/avx512)."""
    fanout: dict[int, int] = {}
    live = graph.topo_order()
    for nid in live:
        n = graph.nodes[nid]
        for ch in (n.a, n.b, n.c):
            if ch >= 0:
                fanout[ch] = fanout.get(ch, 0) + 1
    singles = {nid for nid in live
               if graph.nodes[nid].op == OP_NOT and fanout.get(nid, 0) == 1}
    return _rebuild(graph, andn_fanout1=singles)


def lib_gate_count(graph: Graph, lib_name: str) -> int:
    """Mapped instruction count, with the neon OR(a, NOT b) == ORN fusion
    accounted (the histogram the paper reports)."""
    count = graph.live_gate_count()
    if lib_name == "neon":
        count -= _count_orn(graph)
    return count


def optimize_mapped(graph: Graph, lib_name: str, iters: int = 2) -> Graph:
    """Tech-map + post-mapping clean-up pipeline.

    Runs the priority-cuts mapper, constant propagation / dead-node
    sweep, then up to ``iters - 1`` additional area-flow remap
    iterations (each candidate kept only if it lowers the mapped
    instruction count), and finally ANDN absorption for libraries that
    have the cell.  Semantics-preserving; tests re-verify outputs."""
    lib = CELL_LIBS[lib_name]()
    best = const_prop(tech_map(graph, lib))
    for _ in range(max(0, iters - 1)):
        cand = const_prop(tech_map(best, lib))
        if lib_gate_count(cand, lib_name) < lib_gate_count(best, lib_name):
            best = cand
        else:
            break
    if lib.supports(2, 0b0010) is not None:   # library has an ANDN cell
        cand = absorb_andn(best)
        if lib_gate_count(cand, lib_name) <= lib_gate_count(best, lib_name):
            best = cand
    return best


def gate_report(graph: Graph, libs=None, optimize: bool = True) -> dict:
    """Per-library gate-count report: {lib: {gates, depth, histogram}}.

    ``gates`` is the mapped instruction count after the optimization
    pipeline (or after plain tech mapping when ``optimize=False``)."""
    report = {}
    for lib_name in (libs or CELL_LIBS):
        st = mapped_stats(graph, lib_name, optimize=optimize)
        report[lib_name] = {k: st[k] for k in ("gates", "depth", "histogram")}
    return report


# ---------------------------------------------------------------------------
# Reporting
# ---------------------------------------------------------------------------
def mapped_stats(graph: Graph, lib_name: str, optimize: bool = False) -> dict:
    """Map `graph` for `lib_name`, return {gates, depth, histogram}."""
    if optimize:
        mapped = optimize_mapped(graph, lib_name)
    else:
        mapped = tech_map(graph, CELL_LIBS[lib_name]())
    hist = mapped.op_histogram()
    if lib_name == "neon":
        # OR(a, NOT b) pairs emitted for ORN count as a single instruction
        norn = _count_orn(mapped)
        if norn:
            hist["ORN"] = norn
            hist["OR"] = hist.get("OR", 0) - norn
            hist["NOT"] = hist.get("NOT", 0) - norn
    gates = sum(hist.values())
    return {"lib": lib_name, "gates": gates, "depth": mapped.depth(),
            "histogram": hist, "graph": mapped}


def _count_orn(g: Graph) -> int:
    """Count OR(x, NOT y) where the NOT has no other fanout."""
    fanout: dict[int, int] = {}
    live = g.topo_order()
    for nid in live:
        n = g.nodes[nid]
        for ch in (n.a, n.b, n.c):
            if ch >= 0:
                fanout[ch] = fanout.get(ch, 0) + 1
    cnt = 0
    for nid in live:
        n = g.nodes[nid]
        if n.op != OP_OR:
            continue
        for ch in (n.a, n.b):
            cn = g.nodes[ch]
            if cn.op == OP_NOT and fanout.get(ch, 0) == 1:
                cnt += 1
                break
    return cnt
