"""Word-parallel software FP emulation (the SoftFP-style baseline + oracle).

This module plays two roles:

1. **Oracle** for the bitslice circuits: a second, independent
   implementation of the FloPoCo-semantics multiplier/adder, written as
   conventional integer arithmetic over numpy arrays.  The circuit tests
   check gate-level results against these functions exhaustively for
   small formats.

2. **Baseline** for the throughput benchmarks: the paper compares
   HOBFLOPS against Berkeley SoftFP16, i.e. FP emulated with ordinary
   integer instructions, one element per machine word.  Running these
   functions under ``jax.jit`` (pass ``xp=jax.numpy``) is the TPU/JAX
   equivalent of that baseline: word-parallel integer-op FP emulation,
   against which the bitslice-parallel HOBFLOPS path is measured.

All functions operate on integer *code words* (see
:mod:`repro.core.fpformat` for the layout) held in int64 arrays.
"""
from __future__ import annotations

import numpy as np

from .fpformat import (EXC_INF, EXC_NAN, EXC_NORMAL, EXC_ZERO, RNE, RTZ,
                       FPFormat)

_GUARD = 3  # guard/round/sticky bits carried through the adder datapath


# ---------------------------------------------------------------------------
# Field access
# ---------------------------------------------------------------------------
def _idt(xp):
    return xp.int64 if xp is np else xp.int32


def unpack(codes, fmt: FPFormat, xp=np):
    codes = xp.asarray(codes).astype(_idt(xp))
    frac = codes & ((1 << fmt.w_f) - 1)
    exp = (codes >> fmt.exp_off) & ((1 << fmt.w_e) - 1)
    sign = (codes >> fmt.sign_off) & 1
    exc = (codes >> fmt.exc_off) & 3
    return exc, sign, exp, frac


def pack(exc, sign, exp, frac, fmt: FPFormat, xp=np):
    exc = xp.asarray(exc).astype(_idt(xp))
    normal = exc == EXC_NORMAL
    # Canonicalize: non-normal values carry zero exp/frac fields.
    exp = xp.where(normal, exp, 0).astype(_idt(xp))
    frac = xp.where(normal, frac, 0).astype(_idt(xp))
    sign = xp.asarray(sign).astype(_idt(xp))
    return (frac | (exp << fmt.exp_off) | (sign << fmt.sign_off)
            | (exc << fmt.exc_off))


# ---------------------------------------------------------------------------
# float64 <-> code conversion (host side, numpy only)
# ---------------------------------------------------------------------------
def encode(x, fmt: FPFormat, rounding: str = RNE) -> np.ndarray:
    """Quantize float64 values into HOBFLOPS code words."""
    x = np.asarray(x, dtype=np.float64)
    out_shape = x.shape
    x = np.atleast_1d(x)

    isnan = np.isnan(x)
    isinf = np.isinf(x)
    sign = (np.signbit(x)).astype(np.int64)
    ax = np.abs(np.where(isnan | isinf, 1.0, x))

    m, e = np.frexp(ax)                 # ax = m * 2^e, m in [0.5, 1)
    sig = m * 2.0                       # [1, 2)
    e = e - 1
    scaled = (sig - 1.0) * float(1 << fmt.w_f)   # exact in f64 for w_f<=40
    if rounding == RNE:
        frac = np.rint(scaled).astype(np.int64)  # rint = half-to-even
    elif rounding == RTZ:
        frac = np.floor(scaled).astype(np.int64)
    else:
        raise ValueError(rounding)
    carry = frac >= (1 << fmt.w_f)
    frac = np.where(carry, 0, frac)
    e = e + carry
    biased = e + fmt.bias

    exc = np.full(x.shape, EXC_NORMAL, dtype=np.int64)
    exc = np.where(biased < 0, EXC_ZERO, exc)           # flush to zero
    exc = np.where(biased > fmt.emax, EXC_INF, exc)     # overflow
    exc = np.where(ax == 0.0, EXC_ZERO, exc)
    exc = np.where(isinf, EXC_INF, exc)
    exc = np.where(isnan, EXC_NAN, exc)
    sign = np.where(isnan, 0, sign)
    # Underflow flush produces +0 (FloPoCo-flavored; the adder/mul
    # datapaths do the same) — true -0.0 inputs keep their sign.
    sign = np.where((biased < 0) & (ax != 0.0), 0, sign)

    biased = np.clip(biased, 0, fmt.emax)
    return pack(exc, sign, biased, frac, fmt).reshape(out_shape)


def decode(codes, fmt: FPFormat) -> np.ndarray:
    codes = np.atleast_1d(np.asarray(codes))
    exc, sign, exp, frac = unpack(codes, fmt)
    sig = 1.0 + frac.astype(np.float64) / float(1 << fmt.w_f)
    val = np.ldexp(sig, (exp - fmt.bias).astype(np.int64))
    val = np.where(sign == 1, -val, val)
    val = np.where(exc == EXC_ZERO, np.where(sign == 1, -0.0, 0.0), val)
    val = np.where(exc == EXC_INF, np.where(sign == 1, -np.inf, np.inf), val)
    val = np.where(exc == EXC_NAN, np.nan, val)
    return val.reshape(np.asarray(codes).shape)


# ---------------------------------------------------------------------------
# Rounding helper: value has `drop` low bits to discard.
# ---------------------------------------------------------------------------
def _round_drop(value, drop: int, rounding: str, xp=np):
    """Round `value` (int64) down by `drop` bits. Returns rounded value."""
    if drop <= 0:
        return value << (-drop)
    kept = value >> drop
    if rounding == RTZ:
        return kept
    rnd = (value >> (drop - 1)) & 1
    if drop >= 2:
        sticky = (value & ((1 << (drop - 1)) - 1)) != 0
    else:
        sticky = xp.zeros_like(value, dtype=bool)
    lsb = kept & 1
    round_up = (rnd == 1) & (sticky | (lsb == 1))
    return kept + round_up.astype(_idt(xp))


# ---------------------------------------------------------------------------
# Multiplier: (fmt_in, fmt_in) -> fmt_out
# ---------------------------------------------------------------------------
def fp_mul(x, y, fmt_in: FPFormat, fmt_out: FPFormat,
           rounding: str = RNE, xp=np):
    """FloPoCo-semantics FP multiply.  fmt_out.w_e must equal fmt_in.w_e."""
    assert fmt_out.w_e == fmt_in.w_e
    wf = fmt_in.w_f
    exc_x, sx, ex, fx = unpack(x, fmt_in, xp)
    exc_y, sy, ey, fy = unpack(y, fmt_in, xp)

    sign = sx ^ sy
    sig_x = fx | (1 << wf)
    sig_y = fy | (1 << wf)
    prod = sig_x * sig_y                      # in [2^(2wf), 2^(2wf+2))
    norm = (prod >> (2 * wf + 1)) & 1         # product >= 2.0
    # Normalized significand 1.f with 2wf+1 fraction bits.
    frac_full = xp.where(norm == 1,
                         prod & ((1 << (2 * wf + 1)) - 1),
                         (prod << 1) & ((1 << (2 * wf + 1)) - 1))
    drop = (2 * wf + 1) - fmt_out.w_f
    frac_r = _round_drop(frac_full, drop, rounding, xp)
    carry = (frac_r >> fmt_out.w_f) & 1       # rounding overflowed to 2.0
    frac_r = xp.where(carry == 1, 0, frac_r) & ((1 << fmt_out.w_f) - 1)

    e_res = ex + ey - fmt_in.bias + norm + carry
    underflow = e_res < 0
    overflow = e_res > fmt_out.emax

    x_nan, y_nan = exc_x == EXC_NAN, exc_y == EXC_NAN
    x_inf, y_inf = exc_x == EXC_INF, exc_y == EXC_INF
    x_zero, y_zero = exc_x == EXC_ZERO, exc_y == EXC_ZERO
    x_norm, y_norm = exc_x == EXC_NORMAL, exc_y == EXC_NORMAL

    nan = x_nan | y_nan | (x_inf & y_zero) | (x_zero & y_inf)
    inf = (~nan) & ((x_inf & (y_inf | y_norm)) | (y_inf & x_norm)
                    | (x_norm & y_norm & overflow))
    zero = (~nan) & (~inf) & ((x_zero & (y_zero | y_norm))
                              | (y_zero & x_norm)
                              | (x_norm & y_norm & underflow))
    exc = xp.where(nan, EXC_NAN,
                   xp.where(inf, EXC_INF,
                            xp.where(zero, EXC_ZERO, EXC_NORMAL)))
    sign = xp.where(nan, 0, sign)
    # underflow-flushed zeros are +0 (zero-operand products keep the
    # IEEE XOR sign)
    sign = xp.where(x_norm & y_norm & underflow & zero, 0, sign)
    e_res = xp.clip(e_res, 0, fmt_out.emax)
    return pack(exc, sign, e_res, frac_r, fmt_out, xp)


# ---------------------------------------------------------------------------
# Adder: (fmt, fmt) -> fmt
# ---------------------------------------------------------------------------
def fp_add(x, y, fmt: FPFormat, rounding: str = RNE, xp=np):
    """FloPoCo-semantics FP add (single datapath, flush-to-zero)."""
    wf, G = fmt.w_f, _GUARD
    W = wf + 1 + G                       # significand width incl guards
    exc_x, sx, ex, fx = unpack(x, fmt, xp)
    exc_y, sy, ey, fy = unpack(y, fmt, xp)

    # Treat non-normal operands as magnitude-0 on the datapath; exception
    # logic overrides the result afterwards.
    x_norm = exc_x == EXC_NORMAL
    y_norm = exc_y == EXC_NORMAL
    mag_x = xp.where(x_norm, (ex << wf) | fx, -1)   # -1 so zeros lose swaps
    mag_y = xp.where(y_norm, (ey << wf) | fy, -1)

    swap = mag_y > mag_x
    s_big = xp.where(swap, sy, sx)
    e_big = xp.where(swap, ey, ex)
    f_big = xp.where(swap, fy, fx)
    e_sml = xp.where(swap, ex, ey)
    f_sml = xp.where(swap, fx, fy)
    big_norm = xp.where(swap, y_norm, x_norm)
    sml_norm = xp.where(swap, x_norm, y_norm)

    sig_big = xp.where(big_norm, (f_big | (1 << wf)) << G, 0)
    sig_sml_full = xp.where(sml_norm, (f_sml | (1 << wf)) << G, 0)
    d = xp.clip(e_big - e_sml, 0, W + 1)
    sig_sml = sig_sml_full >> d
    sticky_in = (sig_sml_full & ((1 << d) - 1)) != 0
    sig_sml = sig_sml | sticky_in.astype(_idt(xp))

    sub = (sx ^ sy) == 1
    mag = xp.where(sub, sig_big - sig_sml, sig_big + sig_sml)  # W+1 bits
    mag_zero = mag == 0

    # Normalize: find leading one position p (bit index), shift so the
    # leading one lands at bit W-1 (i.e. weight 1.0 before the G guards).
    # p == W means carry-out (add case): shift right 1.
    def _lead(m):
        # highest set bit index of m (m > 0); vectorized.
        p = xp.zeros_like(m)
        for b in range(W + 1):
            p = xp.where((m >> b) & 1 == 1, b, p)
        return p

    p = _lead(xp.where(mag_zero, 1, mag))
    shl = (W - 1) - p                    # >0: shift left; -1: shift right
    carry_case = shl < 0
    mag_l = mag << xp.clip(shl, 0, W)
    lost = mag & 1                       # bit lost when shifting right 1
    mag_r = (mag >> 1) | lost            # keep sticky
    mag_n = xp.where(carry_case, mag_r, mag_l)
    e_res = e_big - xp.clip(shl, -1, W)  # e - shl  (+1 in carry case)

    frac_r = _round_drop(mag_n, G, rounding, xp)         # wf+1 bits + carry
    rcarry = (frac_r >> (wf + 1)) & 1
    frac_r = xp.where(rcarry == 1, frac_r >> 1, frac_r)
    e_res = e_res + rcarry
    frac_out = frac_r & ((1 << wf) - 1)

    underflow = e_res < 0
    overflow = e_res > fmt.emax

    x_nan, y_nan = exc_x == EXC_NAN, exc_y == EXC_NAN
    x_inf, y_inf = exc_x == EXC_INF, exc_y == EXC_INF
    x_zero, y_zero = exc_x == EXC_ZERO, exc_y == EXC_ZERO

    nan = x_nan | y_nan | (x_inf & y_inf & sub)
    inf = (~nan) & (x_inf | y_inf | (x_norm & y_norm & overflow))
    # zero result: both zero, or exact cancellation, or underflow flush
    cancel = x_norm & y_norm & mag_zero
    zero = (~nan) & (~inf) & ((x_zero & y_zero) | cancel
                              | (x_norm & y_norm & underflow))
    # pass-through: one operand zero, other normal
    pass_x = x_norm & y_zero
    pass_y = y_norm & x_zero

    exc = xp.where(nan, EXC_NAN,
                   xp.where(inf, EXC_INF,
                            xp.where(zero, EXC_ZERO, EXC_NORMAL)))
    sign = xp.where(x_inf, sx, xp.where(y_inf, sy, s_big))
    sign = xp.where(zero & ~(x_zero & y_zero), 0, sign)     # exact cancel -> +0
    sign = xp.where(x_zero & y_zero, sx & sy, sign)
    sign = xp.where(nan, 0, sign)

    e_out = xp.clip(e_res, 0, fmt.emax)
    f_out = frac_out
    e_out = xp.where(pass_x, ex, xp.where(pass_y, ey, e_out))
    f_out = xp.where(pass_x, fx, xp.where(pass_y, fy, f_out))
    sign = xp.where(pass_x, sx, xp.where(pass_y, sy, sign))
    return pack(exc, sign, e_out, f_out, fmt, xp)


# ---------------------------------------------------------------------------
# float32 <-> code conversion as pure integer/bitcast ops (jit-able; this
# is also what the dequantization kernels run on-chip).
# ---------------------------------------------------------------------------
def encode_jnp(x, fmt: FPFormat, rounding: str = RNE):
    """float32 -> codes via bit manipulation (traceable).  Subnormal f32
    inputs flush to zero (FloPoCo semantics has no subnormals anyway)."""
    import jax.numpy as jnp
    from jax import lax

    bits = lax.bitcast_convert_type(jnp.asarray(x, jnp.float32), jnp.int32)
    sign = (bits >> 31) & 1
    exp8 = (bits >> 23) & 0xFF
    frac23 = bits & 0x7FFFFF
    isnan = (exp8 == 255) & (frac23 != 0)
    isinf = (exp8 == 255) & (frac23 == 0)
    iszero = exp8 == 0

    s = 23 - fmt.w_f
    if s > 0:
        keep = frac23 >> s
        if rounding == RNE:
            rem = frac23 & ((1 << s) - 1)
            half = 1 << (s - 1)
            round_up = (rem > half) | ((rem == half) & ((keep & 1) == 1))
            keep = keep + round_up.astype(jnp.int32)
        elif rounding != RTZ:
            raise ValueError(rounding)
    else:
        keep = frac23 << (-s)
    carry = keep >> fmt.w_f
    frac = jnp.where(carry == 1, 0, keep) & ((1 << fmt.w_f) - 1)
    e = exp8 - 127 + fmt.bias + carry

    exc = jnp.where(isnan, EXC_NAN,
                    jnp.where(isinf | (e > fmt.emax), EXC_INF,
                              jnp.where(iszero | (e < 0),
                                        EXC_ZERO, EXC_NORMAL)))
    sign = jnp.where(isnan, 0, sign)
    sign = jnp.where((e < 0) & ~iszero & ~isinf & ~isnan, 0, sign)
    e = jnp.clip(e, 0, fmt.emax)
    return pack(exc, sign, e, frac, fmt, jnp).astype(jnp.int32)


def decode_jnp(codes, fmt: FPFormat):
    """codes -> float32 via bit assembly.  Exact when the format's value
    range maps onto f32 normals (true for all w_e <= 7 formats; for
    w_e == 8 the very bottom exponent decodes as zero)."""
    import jax.numpy as jnp
    from jax import lax

    exc, sign, exp, frac = unpack(jnp.asarray(codes, jnp.int32), fmt, jnp)
    e8 = exp - fmt.bias + 127
    frac32 = (frac << (23 - fmt.w_f)) if fmt.w_f <= 23 else (
        frac >> (fmt.w_f - 23))
    ok = (e8 >= 1) & (e8 <= 254)
    bits = ((sign << 31) | (jnp.clip(e8, 1, 254) << 23)
            | (frac32 & 0x7FFFFF)).astype(jnp.int32)
    val = lax.bitcast_convert_type(bits, jnp.float32)
    val = jnp.where(ok, val, 0.0)
    sgn = jnp.where(sign == 1, -1.0, 1.0).astype(jnp.float32)
    val = jnp.where(exc == EXC_ZERO, 0.0 * sgn, val)
    val = jnp.where(exc == EXC_INF, jnp.inf * sgn, val)
    val = jnp.where(exc == EXC_NAN, jnp.nan, val)
    return val


# ---------------------------------------------------------------------------
# Format cast: (fmt_in) -> fmt_out, re-rounding the significand
# ---------------------------------------------------------------------------
def fp_cast(x, fmt_in: FPFormat, fmt_out: FPFormat, rounding: str = RNE,
            xp=np):
    """FloPoCo-semantics format conversion on code words.

    Re-biases the exponent and re-rounds the significand into
    ``fmt_out`` (exact when ``fmt_out.w_f >= fmt_in.w_f``).  Overflow
    saturates to infinity, underflow flushes to +0 (matching the
    mul/encode datapaths); exact zeros keep their sign.  For formats
    whose values are exactly representable in float32 this agrees
    bit-for-bit with ``encode(decode(x, fmt_in), fmt_out)`` — decode is
    exact, so there is no double rounding.  This is the inter-layer
    boundary operation of the bitslice-resident pipeline (DESIGN.md §8);
    the gate-level twin is ``fpcore.build_cast``.
    """
    exc, sign, exp, frac = unpack(x, fmt_in, xp)
    idt = _idt(xp)

    shift = fmt_out.w_f - fmt_in.w_f
    if shift >= 0:
        frac_r = frac << shift
        carry = xp.zeros_like(frac)
    else:
        frac_r = _round_drop(frac, -shift, rounding, xp)
        carry = (frac_r >> fmt_out.w_f) & 1       # rounded up to 2.0
        frac_r = xp.where(carry == 1, 0, frac_r) & ((1 << fmt_out.w_f) - 1)

    e_res = exp - fmt_in.bias + fmt_out.bias + carry
    underflow = e_res < 0
    overflow = e_res > fmt_out.emax

    x_norm = exc == EXC_NORMAL
    nan = exc == EXC_NAN
    inf = (~nan) & ((exc == EXC_INF) | (x_norm & overflow))
    zero = (~nan) & (~inf) & ((exc == EXC_ZERO) | (x_norm & underflow))
    exc_out = xp.where(nan, EXC_NAN,
                       xp.where(inf, EXC_INF,
                                xp.where(zero, EXC_ZERO, EXC_NORMAL)))
    sign = xp.where(nan, 0, sign)
    sign = xp.where(x_norm & underflow & zero, 0, sign)  # flush is +0
    e_res = xp.clip(e_res, 0, fmt_out.emax).astype(idt)
    return pack(exc_out, sign, e_res, frac_r, fmt_out, xp)


def fp_max(x, y, fmt: FPFormat, xp=np):
    """FloPoCo-semantics FP maximum on code words.

    Ordering: -inf < negative normals < zeros < positive normals < +inf,
    with magnitudes compared as (exp, frac).  NaN propagates: if either
    operand is NaN the result is the canonical +NaN code.  ``max(+0, -0)``
    and ``max(-0, +0)`` are both +0 (a positive sign wins a sign
    disagreement); ``max(-0, -0)`` is -0.  The result is always one of
    the (canonical) operand codes, so no rounding occurs.  Gate-level
    twin: ``fpcore.build_max`` (tests check exhaustive agreement).  This
    is the maxpool reduction op of the plane-resident pipeline.
    """
    idt = _idt(xp)
    exc_x, sx, ex, fx = unpack(x, fmt, xp)
    exc_y, sy, ey, fy = unpack(y, fmt, xp)
    x_norm = exc_x == EXC_NORMAL
    y_norm = exc_y == EXC_NORMAL
    # Magnitude key: (level, exp, frac); level 0=zero, 1=normal, 2=inf.
    # Canonical non-normals carry zero exp/frac so the key is monotone.
    lvl_x = xp.where(exc_x == EXC_INF, 2, xp.where(x_norm, 1, 0))
    lvl_y = xp.where(exc_y == EXC_INF, 2, xp.where(y_norm, 1, 0))
    shift = fmt.w_e + fmt.w_f
    mag_x = (lvl_x.astype(idt) << shift) | xp.where(x_norm, (ex << fmt.w_f)
                                                    | fx, 0)
    mag_y = (lvl_y.astype(idt) << shift) | xp.where(y_norm, (ey << fmt.w_f)
                                                    | fy, 0)
    # signs differ: the non-negative operand wins; same sign: larger
    # magnitude wins when positive, smaller when negative.
    take_y = xp.where(sx != sy, sx == 1,
                      xp.where(sx == 1, mag_y < mag_x, mag_x < mag_y))
    out = xp.where(take_y, xp.asarray(y).astype(idt),
                   xp.asarray(x).astype(idt))
    nan = (exc_x == EXC_NAN) | (exc_y == EXC_NAN)
    nan_code = int(pack(EXC_NAN, 0, 0, 0, fmt))
    return xp.where(nan, nan_code, out)


def fp_scale(x, k: int, fmt: FPFormat, xp=np):
    """FloPoCo-semantics multiply by 2**-k (k >= 0 static) on code words.

    Exact on the significand (a pure exponent decrement); underflow
    flushes to +0 like the mul/cast datapaths; zero/inf/NaN pass
    through.  Gate-level twin: ``fpcore.build_scale``.  With ``k =
    log2(window)`` this is the divider-free final step of an average
    pool (add-tree + scale).
    """
    assert k >= 0, k
    exc, sign, exp, frac = unpack(x, fmt, xp)
    e_res = exp - k
    x_norm = exc == EXC_NORMAL
    underflow = x_norm & (e_res < 0)
    exc_out = xp.where(underflow, EXC_ZERO, exc)
    sign = xp.where(underflow, 0, sign)           # flush is +0
    e_res = xp.clip(e_res, 0, fmt.emax)
    return pack(exc_out, sign, e_res, frac, fmt, xp)


def fp_relu(x, fmt: FPFormat, xp=np):
    """ReLU on code words: any code with the sign bit set — negative
    normals, -0, -inf, and (non-canonical) negative NaN — becomes the
    canonical +0 code; everything else passes through unchanged.
    Canonical NaN carries sign 0 and therefore propagates.  This is the
    word-parallel twin of ``conv2d_bitslice.ops.hobflops_relu_planes``
    (one ANDN per plane); tests check exhaustive agreement.
    """
    idt = _idt(xp)
    codes = xp.asarray(x).astype(idt)
    sign = (codes >> fmt.sign_off) & 1
    return xp.where(sign == 1, 0, codes)


def fp_mac(x, y, acc, fmt_in: FPFormat, fmt_out: FPFormat,
           rounding: str = RNE, xp=np):
    """HOBFLOPS MAC semantics: round the product to fmt_out, then add to
    the fmt_out accumulator (two roundings, per the paper's mult+add)."""
    prod = fp_mul(x, y, fmt_in, fmt_out, rounding, xp)
    return fp_add(prod, acc, fmt_out, rounding, xp)


# ---------------------------------------------------------------------------
# StorageFormat (exception-free) weight quantization, jit-able.
# ---------------------------------------------------------------------------
def encode_storage(x, sfmt, rounding: str = RNE):
    """float32 -> StorageFormat codes (int32).  Saturating: inf/nan and
    overflow clamp to the max-magnitude finite code; underflow flushes
    to the zero code."""
    import jax.numpy as jnp

    fmt = FPFormat(sfmt.w_e, sfmt.w_f)
    codes = encode_jnp(x, fmt, rounding)
    exc, sign, exp, frac = unpack(codes, fmt, jnp)
    # nudge +/-2^-bias (exp=0, frac=0) to frac=1 so code 0 stays "zero"
    frac = jnp.where((exc == EXC_NORMAL) & (exp == 0) & (frac == 0),
                     1, frac)
    # saturate inf/nan to max finite
    sat = (exc == EXC_INF) | (exc == EXC_NAN)
    exp = jnp.where(sat, sfmt.emax, exp)
    frac = jnp.where(sat, (1 << sfmt.w_f) - 1, frac)
    normal = (exc == EXC_NORMAL) | sat
    code = jnp.where(normal,
                     frac | (exp << sfmt.w_f)
                     | (sign << (sfmt.w_e + sfmt.w_f)),
                     0)
    return code.astype(jnp.int32)


def decode_storage(codes, sfmt):
    """StorageFormat codes -> float32 (bit assembly, fully vectorized)."""
    import jax.numpy as jnp
    from jax import lax

    c = jnp.asarray(codes, jnp.int32)
    frac = c & ((1 << sfmt.w_f) - 1)
    exp = (c >> sfmt.w_f) & ((1 << sfmt.w_e) - 1)
    sign = (c >> (sfmt.w_e + sfmt.w_f)) & 1
    e8 = exp - sfmt.bias + 127
    bits = ((sign << 31) | (e8 << 23)
            | (frac << (23 - sfmt.w_f))).astype(jnp.int32)
    val = lax.bitcast_convert_type(bits, jnp.float32)
    return jnp.where(c == 0, 0.0, val)
