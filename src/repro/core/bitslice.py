"""Bitslice (bit-plane) transforms: code words <-> bit planes.

The bitslice layout (paper Fig. 3a) stores N custom-FP code words as
``nbits`` planes; plane ``b``, lane-word ``w`` holds bit ``b`` of codes
``w*L .. w*L+L-1`` packed into one machine word of L lanes.  On TPU we
use int32 lane words (the VPU's native element width); the *effective*
SIMD width is whatever array of lane words we process at once — each
(8, 128) vreg of int32 planes is 32768 parallel 1-bit lanes.
"""
from __future__ import annotations

import numpy as np

try:
    import jax.numpy as jnp
except ImportError:  # pragma: no cover
    jnp = None


# ---------------------------------------------------------------------------
# numpy host-side transforms (uint64 lane words; testing + data prep)
# ---------------------------------------------------------------------------
def pack_planes_np(codes: np.ndarray, nbits: int,
                   lane_bits: int = 64) -> np.ndarray:
    """[N] int codes -> [nbits, ceil(N/lane_bits)] uint64 bit planes."""
    codes = np.asarray(codes, dtype=np.uint64).ravel()
    n = codes.shape[0]
    nwords = -(-n // lane_bits)
    padded = np.zeros(nwords * lane_bits, dtype=np.uint64)
    padded[:n] = codes
    padded = padded.reshape(nwords, lane_bits)
    weights = (np.uint64(1) << np.arange(lane_bits, dtype=np.uint64))
    planes = np.empty((nbits, nwords), dtype=np.uint64)
    for b in range(nbits):
        bits = (padded >> np.uint64(b)) & np.uint64(1)
        planes[b] = (bits * weights).sum(axis=1, dtype=np.uint64)
    return planes


def unpack_planes_np(planes: np.ndarray, n: int,
                     lane_bits: int = 64) -> np.ndarray:
    """[nbits, W] planes -> [n] int64 codes."""
    nbits, nwords = planes.shape
    codes = np.zeros(nwords * lane_bits, dtype=np.int64)
    for b in range(nbits):
        bits = (planes[b][:, None].astype(np.uint64)
                >> np.arange(lane_bits, dtype=np.uint64)) & np.uint64(1)
        codes |= bits.astype(np.int64).ravel() << b
    return codes[:n]


# ---------------------------------------------------------------------------
# jnp transforms (int32 lane words; TPU data path)
# ---------------------------------------------------------------------------
def pack_planes(codes, nbits: int, lane_bits: int = 32):
    """[..., N] int32 codes -> [nbits, ..., N // lane_bits] int32 planes.

    N must be a multiple of lane_bits.  Uses a matmul-free bit-gather so
    it lowers to pure vector ops on TPU.
    """
    assert jnp is not None
    codes = jnp.asarray(codes, dtype=jnp.int32)
    n = codes.shape[-1]
    assert n % lane_bits == 0, f"lane dim {n} % {lane_bits} != 0"
    grouped = codes.reshape(*codes.shape[:-1], n // lane_bits, lane_bits)
    weights = (jnp.int32(1) << jnp.arange(lane_bits, dtype=jnp.int32))
    planes = []
    for b in range(nbits):
        bits = (grouped >> b) & 1
        planes.append((bits * weights).sum(axis=-1).astype(jnp.int32))
    return jnp.stack(planes, axis=0)


def unpack_planes(planes, lane_bits: int = 32):
    """[nbits, ..., W] int32 planes -> [..., W * lane_bits] int32 codes."""
    assert jnp is not None
    nbits = planes.shape[0]
    shifts = jnp.arange(lane_bits, dtype=jnp.int32)
    codes = None
    for b in range(nbits):
        bits = (jnp.right_shift(planes[b][..., None], shifts) & 1)
        term = bits.astype(jnp.int32) << b
        codes = term if codes is None else codes | term
    return codes.reshape(*codes.shape[:-2], -1)
