"""Bitslice (bit-plane) transforms: code words <-> bit planes.

The bitslice layout (paper Fig. 3a) stores N custom-FP code words as
``nbits`` planes; plane ``b``, lane-word ``w`` holds bit ``b`` of codes
``w*L .. w*L+L-1`` packed into one machine word of L lanes.  On TPU we
use int32 lane words (the VPU's native element width); the *effective*
SIMD width is whatever array of lane words we process at once — each
(8, 128) vreg of int32 planes is 32768 parallel 1-bit lanes.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .fpformat import FPFormat

try:
    import jax.numpy as jnp
    from jax import tree_util as _tree_util
except ImportError:  # pragma: no cover
    jnp = None
    _tree_util = None


# ---------------------------------------------------------------------------
# numpy host-side transforms (uint64 lane words; testing + data prep)
# ---------------------------------------------------------------------------
def pack_planes_np(codes: np.ndarray, nbits: int,
                   lane_bits: int = 64) -> np.ndarray:
    """[N] int codes -> [nbits, ceil(N/lane_bits)] uint64 bit planes."""
    codes = np.asarray(codes, dtype=np.uint64).ravel()
    n = codes.shape[0]
    nwords = -(-n // lane_bits)
    padded = np.zeros(nwords * lane_bits, dtype=np.uint64)
    padded[:n] = codes
    padded = padded.reshape(nwords, lane_bits)
    weights = (np.uint64(1) << np.arange(lane_bits, dtype=np.uint64))
    planes = np.empty((nbits, nwords), dtype=np.uint64)
    for b in range(nbits):
        bits = (padded >> np.uint64(b)) & np.uint64(1)
        planes[b] = (bits * weights).sum(axis=1, dtype=np.uint64)
    return planes


def unpack_planes_np(planes: np.ndarray, n: int,
                     lane_bits: int = 64) -> np.ndarray:
    """[nbits, W] planes -> [n] int64 codes."""
    nbits, nwords = planes.shape
    codes = np.zeros(nwords * lane_bits, dtype=np.int64)
    for b in range(nbits):
        bits = (planes[b][:, None].astype(np.uint64)
                >> np.arange(lane_bits, dtype=np.uint64)) & np.uint64(1)
        codes |= bits.astype(np.int64).ravel() << b
    return codes[:n]


# ---------------------------------------------------------------------------
# jnp transforms (int32 lane words; TPU data path)
# ---------------------------------------------------------------------------
def pack_planes(codes, nbits: int, lane_bits: int = 32):
    """[..., N] int32 codes -> [nbits, ..., ceil(N / lane_bits)] int32
    planes.

    N is zero-padded to a multiple of lane_bits internally (mirroring
    ``pack_planes_np``).  Uses a matmul-free bit-gather so it lowers to
    pure vector ops on TPU.
    """
    assert jnp is not None
    codes = jnp.asarray(codes, dtype=jnp.int32)
    n = codes.shape[-1]
    pad = (-n) % lane_bits
    if pad:
        # Mirror pack_planes_np: zero-pad the lane dim to a full word
        # (the all-zero code is +0, the MAC identity).
        widths = [(0, 0)] * (codes.ndim - 1) + [(0, pad)]
        codes = jnp.pad(codes, widths)
        n += pad
    grouped = codes.reshape(*codes.shape[:-1], n // lane_bits, lane_bits)
    weights = (jnp.int32(1) << jnp.arange(lane_bits, dtype=jnp.int32))
    planes = []
    for b in range(nbits):
        bits = (grouped >> b) & 1
        planes.append((bits * weights).sum(axis=-1).astype(jnp.int32))
    return jnp.stack(planes, axis=0)


def unpack_planes(planes, lane_bits: int = 32):
    """[nbits, ..., W] int32 planes -> [..., W * lane_bits] int32 codes."""
    assert jnp is not None
    nbits = planes.shape[0]
    shifts = jnp.arange(lane_bits, dtype=jnp.int32)
    codes = None
    for b in range(nbits):
        bits = (jnp.right_shift(planes[b][..., None], shifts) & 1)
        term = bits.astype(jnp.int32) << b
        codes = term if codes is None else codes | term
    return codes.reshape(*codes.shape[:-2], -1)


def window_gather_planes(planes, shape, kh: int, kw: int, stride: int = 1,
                         pad_h: int = 0, pad_w: int = 0,
                         fill_code: int = 0):
    """Pool-window plane gather: stack kh x kw shifted views of a plane
    array without leaving the bitslice domain.

    ``planes`` is ``[nbits, P, Mw]`` (the activation carrier layout:
    pixels along rows, channels along int32 lanes) and ``shape`` the
    logical NHWC shape.  Because a pooling window combines *pixels* of
    the *same* channel, and channels live in lanes, the gather is pure
    row selection — every lane stays aligned.  Returns
    ``([kh*kw, nbits, B*Ho*Wo, Mw] windows, (Ho, Wo))``; window
    position (i, j) is entry ``i*kw + j``.

    ``pad_h``/``pad_w`` add spatial padding (split low-half-first like
    the im2col SAME convention) whose slots hold ``fill_code`` across
    all lanes — +0 (the add identity) for average pools, -inf (the max
    identity) for max pools.
    """
    assert jnp is not None
    nb, P, Mw = planes.shape
    B, H, W, C = shape
    assert P >= B * H * W, (P, shape)
    x = planes[:, :B * H * W, :].reshape(nb, B, H, W, Mw)
    if pad_h or pad_w:
        ph0, pw0 = pad_h // 2, pad_w // 2
        x = jnp.pad(x, ((0, 0), (0, 0), (ph0, pad_h - ph0),
                        (pw0, pad_w - pw0), (0, 0)))
        if fill_code:
            # Per-plane fill word: all 32 lanes carry bit b of the code.
            fill = jnp.asarray([-((fill_code >> b) & 1) for b in range(nb)],
                               jnp.int32)
            interior = jnp.pad(jnp.ones((H, W), jnp.int32),
                               ((ph0, pad_h - ph0), (pw0, pad_w - pw0)))
            x = jnp.where(interior[None, None, :, :, None] == 0,
                          fill[:, None, None, None, None], x)
    Ho = (x.shape[2] - kh) // stride + 1
    Wo = (x.shape[3] - kw) // stride + 1
    wins = []
    for i in range(kh):
        for j in range(kw):
            wins.append(x[:, :, i:i + (Ho - 1) * stride + 1:stride,
                          j:j + (Wo - 1) * stride + 1:stride, :])
    wins = jnp.stack(wins, axis=0)
    return wins.reshape(kh * kw, nb, B * Ho * Wo, Mw), (Ho, Wo)


# ---------------------------------------------------------------------------
# Bitslice-resident activation carrier (the inter-layer HOBFLOPS tensor)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(eq=False)
class BitsliceActivation:
    """A feature map held in the HOBFLOPS bitslice domain.

    This is the tensor that flows *between* layers of the
    bitslice-resident pipeline (paper §3.4: "data stays in HOBFLOPS
    format between layers"; DESIGN.md §8): the OFM bit planes exactly as
    the MAC kernel emits them, so chaining layers is zero-copy.

    Layout (the kernel's native OFM layout):

    * ``planes`` — ``[fmt.nbits, P, Mw]`` int32: plane ``b``, row ``p``,
      lane word ``w`` holds bit ``b`` of the codes for pixel ``p``,
      channels ``32*w .. 32*w+31`` (channels packed along int32 lanes).
    * ``shape``  — the logical NHWC shape ``(B, H, W, C)``.  ``P`` is
      ``B*H*W`` padded up to the kernel's row blocking and ``Mw*32 >= C``
      (padded rows/lanes hold the all-zero +0 code, the MAC identity).
    * ``fmt``    — the FPFormat of the stored codes (a layer output
      carries the accumulator format ``fmt.mult_out(extended)`` until
      cast back down at the next layer's boundary).

    Registered as a JAX pytree (``planes`` is the only leaf; ``fmt`` and
    ``shape`` ride in the static treedef), so activations pass through
    ``jax.jit`` boundaries with the format as compile-time structure.
    """
    planes: "jnp.ndarray"
    fmt: FPFormat
    shape: tuple[int, int, int, int]

    def __post_init__(self):
        assert len(self.shape) == 4, self.shape
        # jax may unflatten with non-array placeholders; only check
        # real (possibly traced) arrays.
        if getattr(self.planes, "ndim", None) == 3:
            assert self.planes.shape[0] == self.fmt.nbits, \
                (self.planes.shape, self.fmt)

    @property
    def nbits(self) -> int:
        return self.fmt.nbits

    @property
    def n_pixels(self) -> int:
        B, H, W, _ = self.shape
        return B * H * W

    def tree_flatten(self):
        return (self.planes,), (self.fmt, self.shape)

    @classmethod
    def tree_unflatten(cls, aux, children):
        fmt, shape = aux
        return cls(children[0], fmt, shape)


def stack_activations(acts):
    """Coalesce per-request activations into one wave-batched carrier.

    All activations must share the spatial/channel geometry ``(H, W, C)``
    and the format; batch counts may differ (heterogeneous requests).
    Because the carrier's row axis is ``B*H*W`` — the batch lives in
    *rows*, channels in int32 lanes — stacking is pure row
    concatenation: each input is trimmed to its logical ``n_pixels``
    rows (dropping per-activation block padding, which holds only the
    +0 code) and the slabs are joined in order.  The result decodes to
    the row-wise concatenation of the inputs, bit-exactly.
    """
    assert jnp is not None
    assert acts, "stack_activations: need at least one activation"
    fmt = acts[0].fmt
    _, H, W, C = acts[0].shape
    for a in acts:
        assert a.fmt == fmt, (a.fmt, fmt)
        assert a.shape[1:] == (H, W, C), (a.shape, (H, W, C))
    planes = jnp.concatenate([a.planes[:, :a.n_pixels, :] for a in acts],
                             axis=1)
    B = sum(a.shape[0] for a in acts)
    return BitsliceActivation(planes, fmt, (B, H, W, C))


def split_activation(act: BitsliceActivation, batch_sizes):
    """Slice a wave-batched activation back into per-request carriers.

    ``batch_sizes`` are per-request image counts summing to at most the
    wave batch (trailing slack is pad).  The inverse of
    :func:`stack_activations` up to row padding: slicing rows
    ``[off : off + b*H*W]`` recovers exactly the codes each request
    contributed, so round-tripping is bit-exact.
    """
    _, H, W, C = act.shape
    rows = H * W
    assert sum(batch_sizes) <= act.shape[0], (batch_sizes, act.shape)
    out, off = [], 0
    for b in batch_sizes:
        out.append(BitsliceActivation(
            act.planes[:, off:off + b * rows, :], act.fmt, (b, H, W, C)))
        off += b * rows
    return out


if _tree_util is not None:  # pragma: no branch
    _tree_util.register_pytree_node(
        BitsliceActivation,
        BitsliceActivation.tree_flatten,
        BitsliceActivation.tree_unflatten)
