"""Netlist -> software translation (the paper's domain-specific generator).

Three consumers:

* ``eval_netlist``     — numpy interpreter over uint64 bit planes.  Used by
                         the exhaustive correctness tests (the analogue of
                         re-simulating the synthesized netlist against the
                         FloPoCo test bench).
* ``make_jax_fn``      — returns a traceable function over int32 planes;
                         under ``jax.jit`` every gate becomes one XLA
                         elementwise bitwise op over arbitrarily wide
                         arrays (TPU VPU lanes = the paper's SIMD lanes).
* ``emit_source``      — generated C-like JAX source text, for inspection
                         (mirrors the paper's generated C headers).

Gate scheduling: gates are emitted in topological order with a
register-allocation pass that reuses temporaries once their last reader
has executed — the software analogue of the paper's topological sort +
G++ register allocation.
"""
from __future__ import annotations

import weakref
from typing import Callable

import numpy as np

from .circuit import (FALSE, OP_AND, OP_ANDN, OP_CONST, OP_INPUT, OP_LUT3,
                      OP_MUX, OP_NOT, OP_OR, OP_XOR, TRUE, Graph)


def _schedule(graph: Graph):
    """Topo order of live logic nodes + last-use map for temp reuse."""
    order = graph.topo_order()
    last_use: dict[int, int] = {}
    for pos, nid in enumerate(order):
        n = graph.nodes[nid]
        for ch in (n.a, n.b, n.c):
            if ch >= 0:
                last_use[ch] = pos
    return order, last_use


def _slot_schedule(graph: Graph):
    """Register-allocated emission schedule.

    Returns ``(steps, nslots, out_wires)``.  Each step is
    ``(node_id, slot, child_slots, free_after)``: evaluate the node into
    ``slot``, reading operands from ``child_slots`` (-1 marks the
    FALSE/TRUE constants, resolved via the node's child ids), then
    return the ``free_after`` slots to the pool.  Output wires stay
    pinned for the whole schedule.  ``out_wires[name]`` is a list of
    ``("slot", s)`` / ``("const", 0|1)`` descriptors per bus bit.
    ``nslots`` is the peak register count — the analogue of the paper's
    topological sort + G++ register allocation over the generated C.
    """
    order, last_use = _schedule(graph)
    pinned = {w for bus in graph.outputs.values() for w in bus}
    slot_of: dict[int, int] = {}
    free: list[int] = []
    nslots = 0
    steps = []
    for pos, nid in enumerate(order):
        n = graph.nodes[nid]
        if nid in (FALSE, TRUE) or n.op == OP_CONST:
            continue
        if free:
            slot = free.pop()
        else:
            slot = nslots
            nslots += 1
        slot_of[nid] = slot
        children = [ch for ch in (n.a, n.b, n.c) if ch >= 0]
        child_slots = tuple(slot_of.get(ch, -1) for ch in children)
        free_after = [slot_of[ch] for ch in set(children)
                      if ch in slot_of and ch not in pinned
                      and last_use.get(ch, -1) == pos]
        steps.append((nid, slot, child_slots, free_after))
        free.extend(free_after)
    out_wires = {}
    for name, bus in graph.outputs.items():
        descs = []
        for w in bus:
            if w in slot_of:
                descs.append(("slot", slot_of[w]))
            else:
                node = graph.nodes[w]
                assert node.op == OP_CONST, f"unscheduled output wire {w}"
                descs.append(("const", 1 if node.aux else 0))
        out_wires[name] = descs
    return steps, nslots, out_wires


# ---------------------------------------------------------------------------
# numpy interpreter
# ---------------------------------------------------------------------------
def eval_netlist(graph: Graph, inputs: dict[str, np.ndarray],
                 xp=np) -> dict[str, np.ndarray]:
    """Evaluate the circuit over bit planes.

    ``inputs[name]`` must be an array whose leading axis indexes the bits
    of bus ``name`` (shape ``[width, ...lanes]``).  Returns planes of the
    same lane shape for every output bus.
    """
    sample = next(iter(inputs.values()))
    lane_shape = sample.shape[1:]
    dtype = sample.dtype
    if dtype.kind == "u":
        ones = xp.full(lane_shape, dtype.type(~dtype.type(0)), dtype=dtype)
    else:
        ones = xp.full(lane_shape, -1, dtype=dtype)
    zeros = xp.zeros(lane_shape, dtype=dtype)

    val: dict[int, np.ndarray] = {FALSE: zeros, TRUE: ones}
    for nid in graph.topo_order():
        if nid in val:
            continue
        n = graph.nodes[nid]
        if n.op == OP_INPUT:
            name, bit = n.aux
            val[nid] = xp.asarray(inputs[name][bit])
        elif n.op == OP_CONST:
            val[nid] = ones if n.aux else zeros
        elif n.op == OP_NOT:
            val[nid] = ~val[n.a]
        elif n.op == OP_AND:
            val[nid] = val[n.a] & val[n.b]
        elif n.op == OP_OR:
            val[nid] = val[n.a] | val[n.b]
        elif n.op == OP_XOR:
            val[nid] = val[n.a] ^ val[n.b]
        elif n.op == OP_ANDN:
            val[nid] = val[n.a] & ~val[n.b]
        elif n.op == OP_MUX:
            s, a, b = val[n.a], val[n.b], val[n.c]
            val[nid] = (s & a) | (~s & b)
        elif n.op == OP_LUT3:
            a, b, c = val[n.a], val[n.b], val[n.c]
            tt = n.aux
            acc = zeros
            for m in range(8):
                if (tt >> m) & 1:
                    t = ones
                    t = t & (a if m & 1 else ~a)
                    t = t & (b if m & 2 else ~b)
                    t = t & (c if m & 4 else ~c)
                    acc = acc | t
            val[nid] = acc
        else:  # pragma: no cover
            raise ValueError(f"bad op {n.op}")
    return {name: xp.stack([val[w] for w in bus])
            for name, bus in graph.outputs.items()}


# ---------------------------------------------------------------------------
# JAX emission
# ---------------------------------------------------------------------------
# One compiled fn per live Graph object: repeated launches of the same
# netlist (every kernel call, every scan trace) reuse the schedule and
# the closure instead of re-running register allocation.
_FN_CACHE: "weakref.WeakKeyDictionary[Graph, Callable]" = \
    weakref.WeakKeyDictionary()


def make_jax_fn(graph: Graph) -> Callable:
    """Returns f(**{name: planes}) -> {name: planes} traceable by JAX.

    Planes are int arrays [width, ...lanes]; each gate traces to one
    bitwise XLA op (MUX/LUT3 expand to their 2-input forms — the TPU VPU
    has no ternary bitwise instruction, see DESIGN.md §2).

    Gates execute on a slot-allocated schedule: temporaries are freed at
    their last use and slots reused, so the trace's peak live-value set
    matches a register-allocated C emission rather than growing with the
    netlist (and JAX's tracer never holds dead intermediates).
    Results are cached per Graph instance.
    """
    cached = _FN_CACHE.get(graph)
    if cached is not None:
        return cached

    import jax.numpy as jnp

    steps, nslots, out_wires = _slot_schedule(graph)
    nodes = graph.nodes

    def fn(**inputs):
        sample = next(iter(inputs.values()))
        zeros = jnp.zeros_like(sample[0])
        ones = ~zeros
        env: list = [None] * nslots

        def rd(slot, child):
            if slot >= 0:
                return env[slot]
            return ones if child == TRUE else zeros

        for nid, slot, cs, free_after in steps:
            n = nodes[nid]
            if n.op == OP_INPUT:
                name, bit = n.aux
                v = inputs[name][bit]
            elif n.op == OP_NOT:
                v = ~rd(cs[0], n.a)
            elif n.op == OP_AND:
                v = rd(cs[0], n.a) & rd(cs[1], n.b)
            elif n.op == OP_OR:
                v = rd(cs[0], n.a) | rd(cs[1], n.b)
            elif n.op == OP_XOR:
                v = rd(cs[0], n.a) ^ rd(cs[1], n.b)
            elif n.op == OP_ANDN:
                v = rd(cs[0], n.a) & ~rd(cs[1], n.b)
            elif n.op == OP_MUX:
                s, a, b = rd(cs[0], n.a), rd(cs[1], n.b), rd(cs[2], n.c)
                v = b ^ (s & (a ^ b))   # 3-op mux
            elif n.op == OP_LUT3:
                a, b, c = rd(cs[0], n.a), rd(cs[1], n.b), rd(cs[2], n.c)
                tt = n.aux
                v = zeros
                for m in range(8):
                    if (tt >> m) & 1:
                        t = (a if m & 1 else ~a)
                        t = t & (b if m & 2 else ~b)
                        t = t & (c if m & 4 else ~c)
                        v = v | t
            else:  # pragma: no cover
                raise ValueError(f"bad op {n.op}")
            for f in free_after:
                env[f] = None
            env[slot] = v
        out = {}
        for name, descs in out_wires.items():
            planes = [env[s] if kind == "slot" else (ones if s else zeros)
                      for kind, s in descs]
            shape = jnp.broadcast_shapes(*(getattr(p, "shape", ())
                                           for p in planes))
            out[name] = jnp.stack([jnp.broadcast_to(p, shape)
                                   for p in planes])
        return out

    _FN_CACHE[graph] = fn
    return fn


# ---------------------------------------------------------------------------
# Source emission (for inspection / documentation)
# ---------------------------------------------------------------------------
_OPFMT = {
    OP_NOT: "t{y} = ~{a}",
    OP_AND: "t{y} = {a} & {b}",
    OP_OR: "t{y} = {a} | {b}",
    OP_XOR: "t{y} = {a} ^ {b}",
    OP_ANDN: "t{y} = {a} & ~{b}",
    OP_MUX: "t{y} = ({a} & {b}) | (~{a} & {c})",
}


def emit_source(graph: Graph, name: str = "circuit") -> str:
    """Readable generated-code listing (one line per cell instance)."""
    lines = [f"def {name}(inputs):"]
    ref: dict[int, str] = {FALSE: "ZERO", TRUE: "ONES"}
    for nid in graph.topo_order():
        n = graph.nodes[nid]
        if n.op == OP_CONST:
            continue
        if n.op == OP_INPUT:
            nm, bit = n.aux
            ref[nid] = f"{nm}[{bit}]"
            continue
        args = {k: ref[getattr(n, k)] for k in ("a", "b", "c")
                if getattr(n, k) >= 0}
        if n.op == OP_LUT3:
            lines.append(f"    t{nid} = LUT{n.aux:03d}({args['a']}, "
                         f"{args['b']}, {args['c']})")
        else:
            lines.append("    " + _OPFMT[n.op].format(y=nid, **args))
        ref[nid] = f"t{nid}"
    for nm, bus in graph.outputs.items():
        lines.append(f"    {nm} = [" + ", ".join(ref[w] for w in bus) + "]")
    lines.append("    return {" + ", ".join(
        f"'{nm}': {nm}" for nm in graph.outputs) + "}")
    return "\n".join(lines)
