"""Gate-level FloPoCo-style FP multiplier / adder circuit generators.

These builders play the role of FloPoCo in the paper's flow: they emit
combinational circuits (over :mod:`repro.core.circuit`) implementing
custom-precision FP arithmetic with the exact same semantics as the
word-parallel oracle in :mod:`repro.core.softfloat` — the tests check
bit-exact agreement, exhaustively for small formats.

Circuits assume *canonical* input codes (non-normal values carry zero
exponent/fraction fields), which is what ``softfloat.pack`` and
``softfloat.encode`` produce, and they emit canonical outputs.
"""
from __future__ import annotations

from . import blocks as B
from .circuit import FALSE, TRUE, Graph
from .fpformat import RNE, RTZ, FPFormat

_GUARD = 3  # must match softfloat._GUARD


# ---------------------------------------------------------------------------
# Field helpers
# ---------------------------------------------------------------------------
def split_fields(bus: list[int], fmt: FPFormat):
    """code bus (LSB first) -> (exc2, sign, exp, frac) wire groups."""
    f = bus[0:fmt.w_f]
    e = bus[fmt.w_f:fmt.w_f + fmt.w_e]
    s = bus[fmt.sign_off]
    exc = bus[fmt.exc_off:fmt.exc_off + 2]  # [exc0, exc1]
    return exc, s, e, f


def exc_flags(g: Graph, exc: list[int]):
    """-> (is_zero, is_normal, is_inf, is_nan)."""
    e0, e1 = exc
    return (g.AND(g.NOT(e1), g.NOT(e0)),
            g.AND(g.NOT(e1), e0),
            g.AND(e1, g.NOT(e0)),
            g.AND(e1, e0))


def pack_fields(g: Graph, exc0: int, exc1: int, sign: int,
                exp: list[int], frac: list[int], fmt: FPFormat) -> list[int]:
    """Assemble a canonical code bus: exp/frac masked unless normal."""
    normal = g.AND(g.NOT(exc1), exc0)
    bus = [g.AND(b, normal) for b in frac]
    bus += [g.AND(b, normal) for b in exp]
    bus += [sign, exc0, exc1]
    assert len(bus) == fmt.nbits
    return bus


def _round_bits(g: Graph, kept: list[int], rnd: int, sticky: int,
                rounding: str) -> tuple[list[int], int]:
    """Round `kept` given round bit + sticky.  Returns (rounded, carry)."""
    if rounding == RTZ:
        return list(kept), FALSE
    assert rounding == RNE
    round_up = g.AND(rnd, g.OR(sticky, kept[0]))
    return B.increment(g, kept, round_up)


# ---------------------------------------------------------------------------
# Multiplier
# ---------------------------------------------------------------------------
def mul_wires(g: Graph, x: list[int], y: list[int], fmt_in: FPFormat,
              fmt_out: FPFormat, rounding: str = RNE) -> list[int]:
    assert fmt_out.w_e == fmt_in.w_e
    wf, we = fmt_in.w_f, fmt_in.w_e
    exc_x, sx, ex, fx = split_fields(x, fmt_in)
    exc_y, sy, ey, fy = split_fields(y, fmt_in)
    x_zero, x_norm, x_inf, x_nan = exc_flags(g, exc_x)
    y_zero, y_norm, y_inf, y_nan = exc_flags(g, exc_y)

    sign = g.XOR(sx, sy)

    # Exact significand product (2wf+2 bits).
    prod = B.mul_unsigned(g, fx + [TRUE], fy + [TRUE])
    norm = prod[2 * wf + 1]
    # Normalized 1.f significand: 2wf+1 fraction bits.
    frac_full = [g.MUX(norm, prod[i], prod[i - 1] if i > 0 else FALSE)
                 for i in range(2 * wf + 1)]

    drop = (2 * wf + 1) - fmt_out.w_f
    if drop < 0:
        frac_r, carry = [FALSE] * (-drop) + frac_full, FALSE
    elif drop == 0:
        frac_r, carry = frac_full, FALSE
    else:
        kept = frac_full[drop:]
        rnd = frac_full[drop - 1]
        sticky = B.or_reduce(g, frac_full[:drop - 1])
        frac_r, carry = _round_bits(g, kept, rnd, sticky, rounding)
    frac_r = frac_r[:fmt_out.w_f]  # on carry the increment wrapped to 0

    # e_res = ex + ey + norm + carry - bias, in we+2-bit two's complement.
    # Two fused ripple chains: (ex + ey + norm), then (+ (2^W - bias) + carry).
    W = we + 2
    e_sum, _ = B.ripple_add(g, ex, ey, cin=norm, width=W)
    e_res, _ = B.ripple_add(g, e_sum,
                            B.const_bus(g, (1 << W) - fmt_in.bias, W),
                            cin=carry, width=W)
    neg = e_res[W - 1]
    underflow = neg
    overflow = g.AND(g.NOT(neg), e_res[we])

    nan = g.OR(g.OR(x_nan, y_nan),
               g.OR(g.AND(x_inf, y_zero), g.AND(x_zero, y_inf)))
    inf_raw = g.OR(g.OR(g.AND(x_inf, g.OR(y_inf, y_norm)),
                        g.AND(y_inf, x_norm)),
                   g.AND(g.AND(x_norm, y_norm), overflow))
    inf = g.AND(g.NOT(nan), inf_raw)
    zero_raw = g.OR(g.OR(g.AND(x_zero, g.OR(y_zero, y_norm)),
                         g.AND(y_zero, x_norm)),
                    g.AND(g.AND(x_norm, y_norm), underflow))
    zero = g.AND(g.AND(g.NOT(nan), g.NOT(inf)), zero_raw)

    # exc encoding: zero=00 normal=01 inf=10 nan=11
    exc1 = g.OR(nan, inf)
    exc0 = g.OR(nan, g.AND(g.NOT(g.OR(inf, zero)), TRUE))
    # exc0 = nan | normal;  normal = !nan & !inf & !zero
    normal = g.AND(g.NOT(nan), g.AND(g.NOT(inf), g.NOT(zero)))
    exc0 = g.OR(nan, normal)

    # underflow-flushed zeros are +0; zero-operand products keep XOR sign
    uf_zero = g.AND(g.AND(g.AND(x_norm, y_norm), underflow), zero)
    sign_out = g.AND(sign, g.NOT(g.OR(nan, uf_zero)))
    return pack_fields(g, exc0, exc1, sign_out, e_res[:we], frac_r, fmt_out)


def build_mul(fmt_in: FPFormat, fmt_out: FPFormat,
              rounding: str = RNE) -> Graph:
    g = Graph()
    x = g.input_bus("x", fmt_in.nbits)
    y = g.input_bus("y", fmt_in.nbits)
    g.output_bus("out", mul_wires(g, x, y, fmt_in, fmt_out, rounding))
    return g


# ---------------------------------------------------------------------------
# Adder
# ---------------------------------------------------------------------------
def add_wires(g: Graph, x: list[int], y: list[int], fmt: FPFormat,
              rounding: str = RNE) -> list[int]:
    wf, we, G = fmt.w_f, fmt.w_e, _GUARD
    W = wf + 1 + G
    assert wf + G + 2 < (1 << (we + 1)), "exponent range too small for datapath"
    exc_x, sx, ex, fx = split_fields(x, fmt)
    exc_y, sy, ey, fy = split_fields(y, fmt)
    x_zero, x_norm, x_inf, x_nan = exc_flags(g, exc_x)
    y_zero, y_norm, y_inf, y_nan = exc_flags(g, exc_y)

    # Magnitude comparison key: (normal, exp, frac); canonical non-normals
    # have zero fields so they always lose against normals.
    key_x = fx + ex + [x_norm]
    key_y = fy + ey + [y_norm]
    swap = B.ult(g, key_x, key_y)

    s_big = g.MUX(swap, sy, sx)
    e_big = B.mux_bus(g, swap, ey, ex)
    f_big = B.mux_bus(g, swap, fy, fx)
    e_sml = B.mux_bus(g, swap, ex, ey)
    f_sml = B.mux_bus(g, swap, fx, fy)
    big_norm = g.MUX(swap, y_norm, x_norm)
    sml_norm = g.MUX(swap, x_norm, y_norm)

    # Significands with G guard bits, gated by the normal flags.
    sig_big = [FALSE] * G + [g.AND(b, big_norm) for b in f_big] + [big_norm]
    sig_sml_full = ([FALSE] * G + [g.AND(b, sml_norm) for b in f_sml]
                    + [sml_norm])

    d, _ = B.ripple_sub(g, e_big, e_sml)  # >= 0 for canonical inputs
    sig_sml, sticky_in = B.shr_barrel(g, sig_sml_full, d, collect_sticky=True)
    sig_sml = [g.OR(sig_sml[0], sticky_in)] + sig_sml[1:]

    sub = g.XOR(sx, sy)
    addend = [g.XOR(b, sub) for b in sig_sml]
    summ, cout = B.ripple_add(g, sig_big, addend, cin=sub, width=W)
    mag = summ + [g.AND(cout, g.NOT(sub))]          # W+1 bits
    mag_zero = B.eq_zero(g, mag)

    carry_case = mag[W]
    # carry path: shift right one, keeping bit0 as sticky
    mag_r = [g.OR(mag[1], mag[0])] + mag[2:W + 1]   # W bits
    # left path: fused leading-zero count + shift (normalizer)
    mag_low = B.mux_bus(g, mag_zero, B.const_bus(g, 1, W), mag[:W])
    mag_l, lz = B.normalize_shift(g, mag_low)
    mag_n = B.mux_bus(g, carry_case, mag_r, mag_l)  # W bits, MSB normalized

    # e_res = e_big + 1 (carry) or e_big - lz, in we+2-bit two's complement
    WE = we + 2
    e_ext = list(e_big) + [FALSE, FALSE]
    e_inc, _ = B.ripple_add(g, e_ext, B.const_bus(g, 1, WE), width=WE)
    e_dec, _ = B.ripple_sub(g, e_ext, lz + [FALSE] * (WE - len(lz)), width=WE)
    e_res = B.mux_bus(g, carry_case, e_inc, e_dec)

    # rounding on the G guard bits
    kept = mag_n[G:]                                # wf+1 bits
    rnd = mag_n[G - 1]
    sticky = B.or_reduce(g, mag_n[:G - 1])
    frac_r, rcarry = _round_bits(g, kept, rnd, sticky, rounding)
    frac_out = frac_r[:wf]                          # on rcarry this is 0
    e_res, _ = B.ripple_add(g, e_res, B.const_bus(g, 0, WE),
                            cin=rcarry, width=WE)

    neg = e_res[WE - 1]
    underflow = neg
    overflow = g.AND(g.NOT(neg), e_res[we])

    both_norm = g.AND(x_norm, y_norm)
    nan = g.OR(g.OR(x_nan, y_nan), g.AND(g.AND(x_inf, y_inf), sub))
    inf = g.AND(g.NOT(nan),
                g.OR(g.OR(x_inf, y_inf), g.AND(both_norm, overflow)))
    cancel = g.AND(both_norm, mag_zero)
    both_zero = g.AND(x_zero, y_zero)
    zero = g.AND(g.AND(g.NOT(nan), g.NOT(inf)),
                 g.OR(g.OR(both_zero, cancel),
                      g.AND(both_norm, underflow)))
    pass_x = g.AND(x_norm, y_zero)
    pass_y = g.AND(y_norm, x_zero)
    normal = g.AND(g.NOT(nan), g.AND(g.NOT(inf), g.NOT(zero)))

    exc1 = g.OR(nan, inf)
    exc0 = g.OR(nan, normal)

    sign = g.MUX(x_inf, sx, g.MUX(y_inf, sy, s_big))
    sign = g.MUX(g.AND(zero, g.NOT(both_zero)), FALSE, sign)
    sign = g.MUX(both_zero, g.AND(sx, sy), sign)
    sign = g.AND(sign, g.NOT(nan))

    e_out = B.mux_bus(g, pass_x, ex, B.mux_bus(g, pass_y, ey, e_res[:we]))
    f_out = B.mux_bus(g, pass_x, fx, B.mux_bus(g, pass_y, fy, frac_out))
    sign = g.MUX(pass_x, sx, g.MUX(pass_y, sy, sign))
    return pack_fields(g, exc0, exc1, sign, e_out, f_out, fmt)


def build_add(fmt: FPFormat, rounding: str = RNE) -> Graph:
    g = Graph()
    x = g.input_bus("x", fmt.nbits)
    y = g.input_bus("y", fmt.nbits)
    g.output_bus("out", add_wires(g, x, y, fmt, rounding))
    return g


# ---------------------------------------------------------------------------
# Fused MAC circuit: out = add(mul(x, y), acc) at accumulator precision.
# ---------------------------------------------------------------------------
def build_mac(fmt_in: FPFormat, extended: bool = False,
              rounding: str = RNE) -> Graph:
    fmt_out = fmt_in.mult_out(extended)
    g = Graph()
    x = g.input_bus("x", fmt_in.nbits)
    y = g.input_bus("y", fmt_in.nbits)
    acc = g.input_bus("acc", fmt_out.nbits)
    prod = mul_wires(g, x, y, fmt_in, fmt_out, rounding)
    g.output_bus("out", add_wires(g, prod, acc, fmt_out, rounding))
    return g
