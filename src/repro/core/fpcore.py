"""Gate-level FloPoCo-style FP multiplier / adder circuit generators.

These builders play the role of FloPoCo in the paper's flow: they emit
combinational circuits (over :mod:`repro.core.circuit`) implementing
custom-precision FP arithmetic with the exact same semantics as the
word-parallel oracle in :mod:`repro.core.softfloat` — the tests check
bit-exact agreement, exhaustively for small formats.

Circuits assume *canonical* input codes (non-normal values carry zero
exponent/fraction fields), which is what ``softfloat.pack`` and
``softfloat.encode`` produce, and they emit canonical outputs.

Internally the datapath operates on an *unpacked* value (:class:`FPVal`:
decoded exception flags + sign + raw exponent/fraction wires).  Packing
to the canonical code layout masks the fields of non-normal values and
re-encodes the exception bits; unpacking re-decodes them.  A fused
multi-step pipeline (``build_mac_chain``) keeps intermediate results in
unpacked form, so the pack/unpack canonicalization — and its gates — is
paid once per chain instead of once per accumulation step.  This is
sound because every consumer of an FPVal either gates the field wires
by the ``normal`` flag or selects the result from the flags alone, so
garbage exponent/fraction wires on non-normal values never reach an
output (DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses

from . import blocks as B
from .circuit import FALSE, TRUE, Graph
from .fpformat import RNE, RTZ, FPFormat

_GUARD = 3  # must match softfloat._GUARD


# ---------------------------------------------------------------------------
# Field helpers
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class FPVal:
    """An FP value as wires: one-hot exception flags + raw datapath fields.

    ``exp``/``frac`` are only meaningful when ``normal`` is set; packing
    masks them to zero otherwise (the canonical encoding).
    """
    zero: int
    normal: int
    inf: int
    nan: int
    sign: int
    exp: list[int]
    frac: list[int]


def split_fields(bus: list[int], fmt: FPFormat):
    """code bus (LSB first) -> (exc2, sign, exp, frac) wire groups."""
    f = bus[0:fmt.w_f]
    e = bus[fmt.w_f:fmt.w_f + fmt.w_e]
    s = bus[fmt.sign_off]
    exc = bus[fmt.exc_off:fmt.exc_off + 2]  # [exc0, exc1]
    return exc, s, e, f


def exc_flags(g: Graph, exc: list[int]):
    """-> (is_zero, is_normal, is_inf, is_nan)."""
    e0, e1 = exc
    return (g.AND(g.NOT(e1), g.NOT(e0)),
            g.AND(g.NOT(e1), e0),
            g.AND(e1, g.NOT(e0)),
            g.AND(e1, e0))


def unpack_val(g: Graph, bus: list[int], fmt: FPFormat) -> FPVal:
    """Canonical code bus -> unpacked FPVal (flags decoded)."""
    exc, s, e, f = split_fields(bus, fmt)
    zero, normal, inf, nan = exc_flags(g, exc)
    return FPVal(zero, normal, inf, nan, s, list(e), list(f))


def pack_val(g: Graph, v: FPVal, fmt: FPFormat) -> list[int]:
    """Unpacked FPVal -> canonical code bus (fields masked unless normal)."""
    bus = [g.AND(b, v.normal) for b in v.frac[:fmt.w_f]]
    bus += [g.AND(b, v.normal) for b in v.exp[:fmt.w_e]]
    exc1 = g.OR(v.nan, v.inf)
    exc0 = g.OR(v.nan, v.normal)
    bus += [v.sign, exc0, exc1]
    assert len(bus) == fmt.nbits
    return bus


def pack_fields(g: Graph, exc0: int, exc1: int, sign: int,
                exp: list[int], frac: list[int], fmt: FPFormat) -> list[int]:
    """Assemble a canonical code bus: exp/frac masked unless normal."""
    normal = g.AND(g.NOT(exc1), exc0)
    bus = [g.AND(b, normal) for b in frac]
    bus += [g.AND(b, normal) for b in exp]
    bus += [sign, exc0, exc1]
    assert len(bus) == fmt.nbits
    return bus


def _round_bits(g: Graph, kept: list[int], rnd: int, sticky: int,
                rounding: str) -> tuple[list[int], int]:
    """Round `kept` given round bit + sticky.  Returns (rounded, carry)."""
    if rounding == RTZ:
        return list(kept), FALSE
    assert rounding == RNE
    round_up = g.AND(rnd, g.OR(sticky, kept[0]))
    return B.increment(g, kept, round_up)


# ---------------------------------------------------------------------------
# Multiplier
# ---------------------------------------------------------------------------
def mul_val(g: Graph, xv: FPVal, yv: FPVal, fmt_in: FPFormat,
            fmt_out: FPFormat, rounding: str = RNE) -> FPVal:
    """Unpacked-domain FP multiply: FPVal x FPVal -> FPVal."""
    assert fmt_out.w_e == fmt_in.w_e
    wf, we = fmt_in.w_f, fmt_in.w_e
    fx, ex = xv.frac, xv.exp
    fy, ey = yv.frac, yv.exp

    sign = g.XOR(xv.sign, yv.sign)

    # Exact significand product (2wf+2 bits).
    prod = B.mul_unsigned(g, fx + [TRUE], fy + [TRUE])
    norm = prod[2 * wf + 1]
    # Normalized 1.f significand: 2wf+1 fraction bits.
    frac_full = [g.MUX(norm, prod[i], prod[i - 1] if i > 0 else FALSE)
                 for i in range(2 * wf + 1)]

    drop = (2 * wf + 1) - fmt_out.w_f
    if drop < 0:
        frac_r, carry = [FALSE] * (-drop) + frac_full, FALSE
    elif drop == 0:
        frac_r, carry = frac_full, FALSE
    else:
        kept = frac_full[drop:]
        rnd = frac_full[drop - 1]
        sticky = B.or_reduce(g, frac_full[:drop - 1])
        frac_r, carry = _round_bits(g, kept, rnd, sticky, rounding)
    frac_r = frac_r[:fmt_out.w_f]  # on carry the increment wrapped to 0

    # e_res = ex + ey + norm + carry - bias, in we+2-bit two's complement.
    # Two fused ripple chains: (ex + ey + norm), then (+ (2^W - bias) + carry).
    W = we + 2
    e_sum, _ = B.ripple_add(g, ex, ey, cin=norm, width=W)
    e_res, _ = B.ripple_add(g, e_sum,
                            B.const_bus(g, (1 << W) - fmt_in.bias, W),
                            cin=carry, width=W)
    neg = e_res[W - 1]
    underflow = neg
    overflow = g.AND(g.NOT(neg), e_res[we])

    x_zero, x_norm, x_inf, x_nan = xv.zero, xv.normal, xv.inf, xv.nan
    y_zero, y_norm, y_inf, y_nan = yv.zero, yv.normal, yv.inf, yv.nan
    nan = g.OR(g.OR(x_nan, y_nan),
               g.OR(g.AND(x_inf, y_zero), g.AND(x_zero, y_inf)))
    inf_raw = g.OR(g.OR(g.AND(x_inf, g.OR(y_inf, y_norm)),
                        g.AND(y_inf, x_norm)),
                   g.AND(g.AND(x_norm, y_norm), overflow))
    inf = g.AND(g.NOT(nan), inf_raw)
    zero_raw = g.OR(g.OR(g.AND(x_zero, g.OR(y_zero, y_norm)),
                         g.AND(y_zero, x_norm)),
                    g.AND(g.AND(x_norm, y_norm), underflow))
    zero = g.AND(g.AND(g.NOT(nan), g.NOT(inf)), zero_raw)
    normal = g.AND(g.NOT(nan), g.AND(g.NOT(inf), g.NOT(zero)))

    # underflow-flushed zeros are +0; zero-operand products keep XOR sign
    uf_zero = g.AND(g.AND(g.AND(x_norm, y_norm), underflow), zero)
    sign_out = g.AND(sign, g.NOT(g.OR(nan, uf_zero)))
    return FPVal(zero, normal, inf, nan, sign_out, e_res[:we], frac_r)


def mul_wires(g: Graph, x: list[int], y: list[int], fmt_in: FPFormat,
              fmt_out: FPFormat, rounding: str = RNE) -> list[int]:
    v = mul_val(g, unpack_val(g, x, fmt_in), unpack_val(g, y, fmt_in),
                fmt_in, fmt_out, rounding)
    return pack_val(g, v, fmt_out)


def build_mul(fmt_in: FPFormat, fmt_out: FPFormat,
              rounding: str = RNE) -> Graph:
    g = Graph()
    x = g.input_bus("x", fmt_in.nbits)
    y = g.input_bus("y", fmt_in.nbits)
    g.output_bus("out", mul_wires(g, x, y, fmt_in, fmt_out, rounding))
    return g


# ---------------------------------------------------------------------------
# Format cast: rebias exponent + re-round significand into fmt_out
# ---------------------------------------------------------------------------
def cast_val(g: Graph, xv: FPVal, fmt_in: FPFormat, fmt_out: FPFormat,
             rounding: str = RNE) -> FPVal:
    """Unpacked-domain format conversion: FPVal(fmt_in) -> FPVal(fmt_out).

    Gate-level twin of ``softfloat.fp_cast`` (same FloPoCo semantics:
    widening is exact, narrowing re-rounds, overflow saturates to inf,
    underflow flushes to +0, exact zeros keep their sign).  Like
    :func:`mul_val`/:func:`add_val` it tolerates garbage exp/frac wires
    on non-normal inputs — every non-normal outcome is selected from the
    exception flags alone — so it composes after a MAC chain without an
    intervening canonical pack.
    """
    wf_i, wf_o = fmt_in.w_f, fmt_out.w_f
    we_i, we_o = fmt_in.w_e, fmt_out.w_e

    if wf_o >= wf_i:
        frac_r = [FALSE] * (wf_o - wf_i) + list(xv.frac[:wf_i])
        carry = FALSE
    else:
        drop = wf_i - wf_o
        kept = list(xv.frac[drop:wf_i])
        rnd = xv.frac[drop - 1]
        sticky = B.or_reduce(g, xv.frac[:drop - 1])
        frac_r, carry = _round_bits(g, kept, rnd, sticky, rounding)
        frac_r = frac_r[:wf_o]   # on carry the increment wrapped to 0

    # e_res = exp - bias_in + bias_out + carry, two's complement.
    W = max(we_i, we_o) + 2
    delta = (fmt_out.bias - fmt_in.bias) % (1 << W)
    e_ext = list(xv.exp[:we_i]) + [FALSE] * (W - we_i)
    e_res, _ = B.ripple_add(g, e_ext, B.const_bus(g, delta, W),
                            cin=carry, width=W)
    neg = e_res[W - 1]
    underflow = neg
    overflow = g.AND(g.NOT(neg), B.or_reduce(g, e_res[we_o:W - 1]))

    nan = xv.nan
    inf = g.OR(xv.inf, g.AND(xv.normal, overflow))
    uf_zero = g.AND(xv.normal, underflow)
    zero = g.OR(xv.zero, uf_zero)
    normal = g.AND(xv.normal, g.AND(g.NOT(underflow), g.NOT(overflow)))
    sign = g.AND(xv.sign, g.NOT(g.OR(nan, uf_zero)))
    return FPVal(zero, normal, inf, nan, sign, e_res[:we_o], frac_r)


def cast_wires(g: Graph, x: list[int], fmt_in: FPFormat, fmt_out: FPFormat,
               rounding: str = RNE) -> list[int]:
    v = cast_val(g, unpack_val(g, x, fmt_in), fmt_in, fmt_out, rounding)
    return pack_val(g, v, fmt_out)


def build_cast(fmt_in: FPFormat, fmt_out: FPFormat,
               rounding: str = RNE) -> Graph:
    """Combinational fmt_in -> fmt_out converter (input ``x``, output
    ``out``).  The bitslice-resident pipeline maps this through
    ``opt.optimize_mapped`` and runs it once per layer boundary to round
    the accumulator format back to the next layer's operand format."""
    g = Graph()
    x = g.input_bus("x", fmt_in.nbits)
    g.output_bus("out", cast_wires(g, x, fmt_in, fmt_out, rounding))
    return g


# ---------------------------------------------------------------------------
# Maximum: sign/magnitude FP compare-and-select (the maxpool reduction)
# ---------------------------------------------------------------------------
def max_val(g: Graph, xv: FPVal, yv: FPVal, fmt: FPFormat) -> FPVal:
    """Unpacked-domain FP maximum: max(FPVal, FPVal) -> FPVal.

    Gate-level twin of ``softfloat.fp_max``: total order
    -inf < negatives < zeros < positives < +inf, NaN propagating (to
    canonical +NaN), ``max(+0, -0) == +0``.  The datapath is one
    unsigned compare (``blocks.ucmp``) over a magnitude key plus field
    muxes — no rounding, the result is always one of the operands.

    Garbage-safe like :func:`mul_val`/:func:`add_val`: the key gates
    exp/frac by the ``normal`` flag and carries (normal, inf) as its top
    bits, so garbage fields on non-normal values never decide a compare
    against a different exception class, and every non-normal outcome is
    selected by the flags alone.
    """
    # Magnitude key (LSB first): [frac, exp] gated by normal, then the
    # level bits normal < inf (zero = 00, normal = 01, inf = 10).
    key_x = ([g.AND(b, xv.normal) for b in xv.frac + xv.exp]
             + [xv.normal, xv.inf])
    key_y = ([g.AND(b, yv.normal) for b in yv.frac + yv.exp]
             + [yv.normal, yv.inf])
    mag_lt, mag_gt = B.ucmp(g, key_x, key_y)

    # signs differ: the non-negative operand wins; same sign: larger
    # magnitude wins when positive, smaller when negative.
    sign_diff = g.XOR(xv.sign, yv.sign)
    take_y = g.MUX(sign_diff, xv.sign, g.MUX(xv.sign, mag_gt, mag_lt))

    nan = g.OR(xv.nan, yv.nan)
    zero = g.AND(g.MUX(take_y, yv.zero, xv.zero), g.NOT(nan))
    normal = g.AND(g.MUX(take_y, yv.normal, xv.normal), g.NOT(nan))
    inf = g.AND(g.MUX(take_y, yv.inf, xv.inf), g.NOT(nan))
    sign = g.AND(g.MUX(take_y, yv.sign, xv.sign), g.NOT(nan))
    exp = B.mux_bus(g, take_y, yv.exp, xv.exp)
    frac = B.mux_bus(g, take_y, yv.frac, xv.frac)
    return FPVal(zero, normal, inf, nan, sign, exp, frac)


def max_wires(g: Graph, x: list[int], y: list[int],
              fmt: FPFormat) -> list[int]:
    v = max_val(g, unpack_val(g, x, fmt), unpack_val(g, y, fmt), fmt)
    return pack_val(g, v, fmt)


def build_max(fmt: FPFormat) -> Graph:
    """Combinational elementwise FP max (inputs ``x``/``y``, output
    ``out``).  The plane-resident maxpool folds its window through this
    netlist — one compare-select per window element, entirely in the
    bitslice domain."""
    g = Graph()
    x = g.input_bus("x", fmt.nbits)
    y = g.input_bus("y", fmt.nbits)
    g.output_bus("out", max_wires(g, x, y, fmt))
    return g


# ---------------------------------------------------------------------------
# Power-of-two scale: exponent decrement (the avgpool divider)
# ---------------------------------------------------------------------------
def scale_val(g: Graph, xv: FPVal, fmt: FPFormat, k: int) -> FPVal:
    """Unpacked-domain multiply by 2**-k (k >= 0 static): a bare
    exponent decrement, exact on the significand.  Underflow flushes to
    +0 like :func:`cast_val`; zero/inf/NaN pass through.  Gate-level
    twin of ``softfloat.fp_scale``; garbage-safe like the other FPVal
    ops (the decremented exponent is only meaningful when ``normal``
    survives, and non-normal outcomes come from the flags alone).
    """
    assert k >= 0, k
    we = fmt.w_e
    if k > fmt.emax:
        # Every normal underflows (exp <= emax < k): flush them all to
        # +0.  Without this branch const_bus would truncate k to w_e
        # bits and scale by the wrong power.
        zero = g.OR(xv.zero, xv.normal)
        sign = g.AND(xv.sign, g.NOT(g.OR(xv.nan, xv.normal)))
        return FPVal(zero, FALSE, xv.inf, xv.nan, sign,
                     [FALSE] * we, xv.frac)
    diff, borrow = B.ripple_sub(g, xv.exp, B.const_bus(g, k, we))
    uf_zero = g.AND(xv.normal, borrow)
    zero = g.OR(xv.zero, uf_zero)
    normal = g.AND(xv.normal, g.NOT(borrow))
    sign = g.AND(xv.sign, g.NOT(g.OR(xv.nan, uf_zero)))
    return FPVal(zero, normal, xv.inf, xv.nan, sign, diff[:we], xv.frac)


def scale_wires(g: Graph, x: list[int], fmt: FPFormat, k: int) -> list[int]:
    v = scale_val(g, unpack_val(g, x, fmt), fmt, k)
    return pack_val(g, v, fmt)


def build_scale(fmt: FPFormat, k: int) -> Graph:
    """Combinational multiply-by-2**-k (input ``x``, output ``out``).
    With ``k = log2(window)`` this turns an average pool into add-tree +
    scale with no divider — the plane-resident pipeline's avgpool tail."""
    g = Graph()
    x = g.input_bus("x", fmt.nbits)
    g.output_bus("out", scale_wires(g, x, fmt, k))
    return g


# ---------------------------------------------------------------------------
# Adder
# ---------------------------------------------------------------------------
def add_val(g: Graph, xv: FPVal, yv: FPVal, fmt: FPFormat,
            rounding: str = RNE) -> FPVal:
    """Unpacked-domain FP add: FPVal + FPVal -> FPVal.

    Tolerates garbage exp/frac wires on non-normal inputs: the swap
    comparison key carries the ``normal`` flag as its MSB (so a normal
    value always outranks a non-normal one), significands are gated by
    the normal flags before the datapath, and all non-normal outcomes
    are selected by the flag logic alone.
    """
    wf, we, G = fmt.w_f, fmt.w_e, _GUARD
    W = wf + 1 + G
    assert wf + G + 2 < (1 << (we + 1)), "exponent range too small for datapath"
    sx, ex, fx = xv.sign, xv.exp, xv.frac
    sy, ey, fy = yv.sign, yv.exp, yv.frac
    x_zero, x_norm, x_inf, x_nan = xv.zero, xv.normal, xv.inf, xv.nan
    y_zero, y_norm, y_inf, y_nan = yv.zero, yv.normal, yv.inf, yv.nan

    # Magnitude comparison key: (normal, exp, frac); non-normals carry
    # the normal flag as MSB so they always lose against normals, and
    # garbage fields between two non-normals never affect the result.
    key_x = list(fx) + list(ex) + [x_norm]
    key_y = list(fy) + list(ey) + [y_norm]
    swap = B.ult(g, key_x, key_y)

    s_big = g.MUX(swap, sy, sx)
    e_big = B.mux_bus(g, swap, ey, ex)
    f_big = B.mux_bus(g, swap, fy, fx)
    e_sml = B.mux_bus(g, swap, ex, ey)
    f_sml = B.mux_bus(g, swap, fx, fy)
    big_norm = g.MUX(swap, y_norm, x_norm)
    sml_norm = g.MUX(swap, x_norm, y_norm)

    # Significands with G guard bits, gated by the normal flags.
    sig_big = [FALSE] * G + [g.AND(b, big_norm) for b in f_big] + [big_norm]
    sig_sml_full = ([FALSE] * G + [g.AND(b, sml_norm) for b in f_sml]
                    + [sml_norm])

    d, _ = B.ripple_sub(g, e_big, e_sml)  # >= 0 when both operands normal
    sig_sml, sticky_in = B.shr_barrel(g, sig_sml_full, d, collect_sticky=True)
    sig_sml = [g.OR(sig_sml[0], sticky_in)] + sig_sml[1:]

    sub = g.XOR(sx, sy)
    addend = [g.XOR(b, sub) for b in sig_sml]
    summ, cout = B.ripple_add(g, sig_big, addend, cin=sub, width=W)
    mag = summ + [g.AND(cout, g.NOT(sub))]          # W+1 bits
    mag_zero = B.eq_zero(g, mag)

    carry_case = mag[W]
    # carry path: shift right one, keeping bit0 as sticky
    mag_r = [g.OR(mag[1], mag[0])] + mag[2:W + 1]   # W bits
    # left path: fused leading-zero count + shift (normalizer)
    mag_low = B.mux_bus(g, mag_zero, B.const_bus(g, 1, W), mag[:W])
    mag_l, lz = B.normalize_shift(g, mag_low)
    mag_n = B.mux_bus(g, carry_case, mag_r, mag_l)  # W bits, MSB normalized

    # e_res = e_big + 1 (carry) or e_big - lz, in we+2-bit two's complement
    WE = we + 2
    e_ext = list(e_big) + [FALSE, FALSE]
    e_inc, _ = B.ripple_add(g, e_ext, B.const_bus(g, 1, WE), width=WE)
    e_dec, _ = B.ripple_sub(g, e_ext, lz + [FALSE] * (WE - len(lz)), width=WE)
    e_res = B.mux_bus(g, carry_case, e_inc, e_dec)

    # rounding on the G guard bits
    kept = mag_n[G:]                                # wf+1 bits
    rnd = mag_n[G - 1]
    sticky = B.or_reduce(g, mag_n[:G - 1])
    frac_r, rcarry = _round_bits(g, kept, rnd, sticky, rounding)
    frac_out = frac_r[:wf]                          # on rcarry this is 0
    e_res, _ = B.ripple_add(g, e_res, B.const_bus(g, 0, WE),
                            cin=rcarry, width=WE)

    neg = e_res[WE - 1]
    underflow = neg
    overflow = g.AND(g.NOT(neg), e_res[we])

    both_norm = g.AND(x_norm, y_norm)
    nan = g.OR(g.OR(x_nan, y_nan), g.AND(g.AND(x_inf, y_inf), sub))
    inf = g.AND(g.NOT(nan),
                g.OR(g.OR(x_inf, y_inf), g.AND(both_norm, overflow)))
    cancel = g.AND(both_norm, mag_zero)
    both_zero = g.AND(x_zero, y_zero)
    zero = g.AND(g.AND(g.NOT(nan), g.NOT(inf)),
                 g.OR(g.OR(both_zero, cancel),
                      g.AND(both_norm, underflow)))
    pass_x = g.AND(x_norm, y_zero)
    pass_y = g.AND(y_norm, x_zero)
    normal = g.AND(g.NOT(nan), g.AND(g.NOT(inf), g.NOT(zero)))

    sign = g.MUX(x_inf, sx, g.MUX(y_inf, sy, s_big))
    sign = g.MUX(g.AND(zero, g.NOT(both_zero)), FALSE, sign)
    sign = g.MUX(both_zero, g.AND(sx, sy), sign)
    sign = g.AND(sign, g.NOT(nan))

    e_out = B.mux_bus(g, pass_x, ex, B.mux_bus(g, pass_y, ey, e_res[:we]))
    f_out = B.mux_bus(g, pass_x, fx, B.mux_bus(g, pass_y, fy, frac_out))
    sign = g.MUX(pass_x, sx, g.MUX(pass_y, sy, sign))
    return FPVal(zero, normal, inf, nan, sign, e_out, f_out)


def add_wires(g: Graph, x: list[int], y: list[int], fmt: FPFormat,
              rounding: str = RNE) -> list[int]:
    v = add_val(g, unpack_val(g, x, fmt), unpack_val(g, y, fmt),
                fmt, rounding)
    return pack_val(g, v, fmt)


def build_add(fmt: FPFormat, rounding: str = RNE) -> Graph:
    g = Graph()
    x = g.input_bus("x", fmt.nbits)
    y = g.input_bus("y", fmt.nbits)
    g.output_bus("out", add_wires(g, x, y, fmt, rounding))
    return g


# ---------------------------------------------------------------------------
# Fused MAC circuit: out = add(mul(x, y), acc) at accumulator precision.
# ---------------------------------------------------------------------------
def build_mac(fmt_in: FPFormat, extended: bool = False,
              rounding: str = RNE) -> Graph:
    fmt_out = fmt_in.mult_out(extended)
    g = Graph()
    x = g.input_bus("x", fmt_in.nbits)
    y = g.input_bus("y", fmt_in.nbits)
    acc = g.input_bus("acc", fmt_out.nbits)
    prod = mul_wires(g, x, y, fmt_in, fmt_out, rounding)
    g.output_bus("out", add_wires(g, prod, acc, fmt_out, rounding))
    return g


# ---------------------------------------------------------------------------
# Fused K-step MAC chain:
#   out = add(mul(x[k-1], y[k-1]), ... add(mul(x0, y0), acc) ...)
# ---------------------------------------------------------------------------
def build_mac_chain(fmt_in: FPFormat, k: int, extended: bool = False,
                    rounding: str = RNE) -> Graph:
    """K MAC steps fused into one netlist, bit-exact to ``k`` sequential
    :func:`build_mac` applications in channel order.

    Inputs: ``x0..x{k-1}``/``y0..y{k-1}`` (operand format) and ``acc``
    (accumulator format ``fmt_in.mult_out(extended)``); output ``out``.

    The intermediate accumulator stays in unpacked :class:`FPVal` form
    between steps, so the canonical pack (field masking + exception
    re-encode) and the matching unpack (exception re-decode) are elided
    at every mul->add and add->add boundary — 2k-1 boundaries' worth of
    gates per chain, paid once at the chain's output instead.
    """
    assert k >= 1
    fmt_out = fmt_in.mult_out(extended)
    g = Graph()
    xs = [g.input_bus(f"x{i}", fmt_in.nbits) for i in range(k)]
    ys = [g.input_bus(f"y{i}", fmt_in.nbits) for i in range(k)]
    acc = g.input_bus("acc", fmt_out.nbits)
    accv = unpack_val(g, acc, fmt_out)
    for i in range(k):
        xv = unpack_val(g, xs[i], fmt_in)
        yv = unpack_val(g, ys[i], fmt_in)
        pv = mul_val(g, xv, yv, fmt_in, fmt_out, rounding)
        accv = add_val(g, pv, accv, fmt_out, rounding)
    g.output_bus("out", pack_val(g, accv, fmt_out))
    return g
