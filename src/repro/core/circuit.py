"""Gate-level combinational circuit IR with structural hashing.

This module is the in-repo replacement for the paper's FloPoCo -> Cadence
Genus -> Yosys/ABC hardware flow.  Circuits are built as DAGs of 1-bit
logic gates; every gate construction goes through a hash-consing +
constant-folding layer so the graph is kept canonical while it is built
(the software analogue of Genus' area optimization + ABC ``strash``).

A node is identified by an integer id.  Node 0 is constant FALSE and node
1 is constant TRUE.  Buses (multi-bit values) are plain Python lists of
node ids, least-significant bit first.

The IR deliberately mirrors the gate vocabulary of the paper's standard
cell libraries (Table 1): 2-input AND/OR/XOR/ANDN, NOT, the 3-input Arm
Neon SEL (mux), and the AVX512 ternary LUT3.  Construction only ever
emits {NOT, AND, OR, XOR, MUX}; technology mapping (``repro.core.opt``)
re-expresses the graph in terms of a chosen cell library.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

# Op codes -----------------------------------------------------------------
OP_CONST = 0   # aux = 0 or 1
OP_INPUT = 1   # aux = (name, bit_index)
OP_NOT = 2     # a
OP_AND = 3     # a, b
OP_OR = 4      # a, b
OP_XOR = 5     # a, b
OP_ANDN = 6    # a & ~b        (introduced by tech mapping only)
OP_MUX = 7     # s ? a : b     (s=a_field, a=b_field, b=c_field)
OP_LUT3 = 8    # aux = 8-bit truth table over (a, b, c); y = tt[(c<<2)|(b<<1)|a]

OP_NAMES = {
    OP_CONST: "CONST",
    OP_INPUT: "INPUT",
    OP_NOT: "NOT",
    OP_AND: "AND",
    OP_OR: "OR",
    OP_XOR: "XOR",
    OP_ANDN: "ANDN",
    OP_MUX: "MUX",
    OP_LUT3: "LUT3",
}

FALSE = 0
TRUE = 1


@dataclasses.dataclass
class Node:
    op: int
    a: int = -1
    b: int = -1
    c: int = -1
    aux: object = None


class Graph:
    """A combinational circuit under construction.

    Hash-consing guarantees that structurally identical gates share a
    node id, and the constructor helpers apply local boolean
    simplifications (idempotence, annihilation, involution, etc.) so the
    graph never contains the trivially redundant logic a naive netlist
    writer would produce.
    """

    def __init__(self) -> None:
        self.nodes: list[Node] = [Node(OP_CONST, aux=0), Node(OP_CONST, aux=1)]
        self._cse: dict[tuple, int] = {}
        self._not_of: dict[int, int] = {}  # id -> id of its registered inverse
        self.inputs: dict[str, list[int]] = {}   # name -> bus (LSB first)
        self.outputs: dict[str, list[int]] = {}  # name -> bus (LSB first)

    # -- raw node creation --------------------------------------------------
    def _new(self, op: int, a: int = -1, b: int = -1, c: int = -1, aux=None) -> int:
        key = (op, a, b, c, aux)
        hit = self._cse.get(key)
        if hit is not None:
            return hit
        self.nodes.append(Node(op, a, b, c, aux))
        nid = len(self.nodes) - 1
        self._cse[key] = nid
        return nid

    # -- inputs / outputs ---------------------------------------------------
    def input_bus(self, name: str, width: int) -> list[int]:
        if name in self.inputs:
            raise ValueError(f"duplicate input bus {name!r}")
        bus = [self._new(OP_INPUT, aux=(name, i)) for i in range(width)]
        self.inputs[name] = bus
        return bus

    def output_bus(self, name: str, bus: Sequence[int]) -> None:
        if name in self.outputs:
            raise ValueError(f"duplicate output bus {name!r}")
        self.outputs[name] = list(bus)

    # -- logic constructors (with folding) ------------------------------------
    def NOT(self, a: int) -> int:
        if a == FALSE:
            return TRUE
        if a == TRUE:
            return FALSE
        n = self.nodes[a]
        if n.op == OP_NOT:
            return n.a
        hit = self._not_of.get(a)
        if hit is not None:
            return hit
        nid = self._new(OP_NOT, a)
        self._not_of[a] = nid
        self._not_of[nid] = a
        return nid

    def _is_compl(self, a: int, b: int) -> bool:
        return self._not_of.get(a) == b

    def AND(self, a: int, b: int) -> int:
        if a > b:
            a, b = b, a
        if a == FALSE:
            return FALSE
        if a == TRUE:
            return b
        if a == b:
            return a
        if self._is_compl(a, b):
            return FALSE
        return self._new(OP_AND, a, b)

    def OR(self, a: int, b: int) -> int:
        if a > b:
            a, b = b, a
        if a == TRUE:
            return TRUE
        if a == FALSE:
            return b
        if a == b:
            return a
        if self._is_compl(a, b):
            return TRUE
        return self._new(OP_OR, a, b)

    def XOR(self, a: int, b: int) -> int:
        if a > b:
            a, b = b, a
        if a == FALSE:
            return b
        if a == TRUE:
            return self.NOT(b)
        if a == b:
            return FALSE
        if self._is_compl(a, b):
            return TRUE
        return self._new(OP_XOR, a, b)

    def XNOR(self, a: int, b: int) -> int:
        return self.NOT(self.XOR(a, b))

    def NAND(self, a: int, b: int) -> int:
        return self.NOT(self.AND(a, b))

    def NOR(self, a: int, b: int) -> int:
        return self.NOT(self.OR(a, b))

    def MUX(self, s: int, a: int, b: int) -> int:
        """s ? a : b."""
        if s == TRUE:
            return a
        if s == FALSE:
            return b
        if a == b:
            return a
        if a == TRUE and b == FALSE:
            return s
        if a == FALSE and b == TRUE:
            return self.NOT(s)
        if a == s:          # s ? s : b  == s | b... only when a==s -> s?1:b
            a = TRUE
            return self.OR(s, b)
        if b == s:          # s ? a : s  == s & a
            return self.AND(s, a)
        if self._is_compl(s, a):   # s ? ~s : b == ~s & b
            return self.AND(self.NOT(s), b)
        if self._is_compl(s, b):   # s ? a : ~s == ~s | a... s?a:1 when s=0 -> 1
            return self.OR(self.NOT(s), a)
        if a == FALSE:      # s ? 0 : b == ~s & b
            return self.AND(self.NOT(s), b)
        if a == TRUE:       # s ? 1 : b == s | b
            return self.OR(s, b)
        if b == FALSE:      # s ? a : 0 == s & a
            return self.AND(s, a)
        if b == TRUE:       # s ? a : 1 == ~s | a
            return self.OR(self.NOT(s), a)
        if self._is_compl(a, b):   # s ? a : ~a == s XNOR a? check: s=1->a, s=0->~a == ~(s^~a)= s xnor a
            return self.XNOR(s, a)
        return self._new(OP_MUX, s, a, b)

    # Tech-mapping constructors (used by repro.core.opt only) ----------------
    def ANDN(self, a: int, b: int) -> int:
        """a & ~b."""
        if a == FALSE or b == TRUE:
            return FALSE
        if b == FALSE:
            return a
        if a == b:
            return FALSE
        if a == TRUE:
            return self.NOT(b)
        if self._is_compl(a, b):
            return a
        return self._new(OP_ANDN, a, b)

    def LUT3(self, tt: int, a: int, b: int, c: int) -> int:
        """Arbitrary 3-input boolean function, AVX512-ternary style.

        ``tt`` is the 8-bit truth table: output for input pattern
        (c, b, a) is bit ``(c << 2) | (b << 1) | a`` of ``tt``.
        """
        assert 0 <= tt < 256
        if tt == 0:
            return FALSE
        if tt == 255:
            return TRUE
        return self._new(OP_LUT3, a, b, c, aux=tt)

    # -- analysis -------------------------------------------------------------
    def topo_order(self, roots: Iterable[int] | None = None) -> list[int]:
        """Topologically sorted live node ids (inputs/consts included)."""
        if roots is None:
            roots = [w for bus in self.outputs.values() for w in bus]
        seen: set[int] = set()
        order: list[int] = []
        stack: list[tuple[int, bool]] = [(r, False) for r in roots]
        while stack:
            nid, expanded = stack.pop()
            if expanded:
                order.append(nid)
                continue
            if nid in seen:
                continue
            seen.add(nid)
            stack.append((nid, True))
            n = self.nodes[nid]
            for child in (n.a, n.b, n.c):
                if child >= 0 and child not in seen:
                    stack.append((child, False))
        return order

    def live_gate_count(self, ops: Iterable[int] | None = None) -> int:
        """Number of live logic gates (excludes inputs and constants)."""
        logic = set(ops) if ops is not None else {
            OP_NOT, OP_AND, OP_OR, OP_XOR, OP_ANDN, OP_MUX, OP_LUT3}
        return sum(1 for nid in self.topo_order()
                   if self.nodes[nid].op in logic)

    def op_histogram(self) -> dict[str, int]:
        hist: dict[str, int] = {}
        for nid in self.topo_order():
            name = OP_NAMES[self.nodes[nid].op]
            hist[name] = hist.get(name, 0) + 1
        hist.pop("CONST", None)
        hist.pop("INPUT", None)
        return hist

    def depth(self) -> int:
        """Longest combinational path, in gates."""
        d: dict[int, int] = {}
        for nid in self.topo_order():
            n = self.nodes[nid]
            if n.op in (OP_CONST, OP_INPUT):
                d[nid] = 0
            else:
                d[nid] = 1 + max(d.get(ch, 0) for ch in (n.a, n.b, n.c) if ch >= 0)
        return max(d.values(), default=0)

    def stats(self) -> dict:
        return {
            "gates": self.live_gate_count(),
            "depth": self.depth(),
            "histogram": self.op_histogram(),
            "inputs": {k: len(v) for k, v in self.inputs.items()},
            "outputs": {k: len(v) for k, v in self.outputs.items()},
        }
