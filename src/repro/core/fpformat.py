"""FloPoCo-style custom floating-point formats.

A HOBFLOPS number (following FloPoCo's encoding, which the paper uses) is
the bit-vector

    [ exc(2) | sign(1) | exponent(w_e) | fraction(w_f) ]

with the exception field: 00 = zero, 01 = normal, 10 = +/-inf, 11 = NaN.
There are no subnormals; the significand always carries an implicit
leading 1, and every exponent code 0 .. 2^w_e - 1 encodes a normal
number.  Underflow flushes to zero, overflow saturates to infinity.

In an integer code word we store the fraction in the low bits:

    code = frac | exp << w_f | sign << (w_f+w_e) | exc << (w_f+w_e+1)

NINBITS per the paper == FPFormat.nbits == 2 + 1 + w_e + w_f.
"""
from __future__ import annotations

import dataclasses

EXC_ZERO = 0
EXC_NORMAL = 1
EXC_INF = 2
EXC_NAN = 3

RNE = "rne"  # round to nearest, ties to even
RTZ = "rtz"  # round towards zero


@dataclasses.dataclass(frozen=True, order=True)
class FPFormat:
    """A custom-precision FP format: w_e exponent bits, w_f fraction bits."""
    w_e: int
    w_f: int

    def __post_init__(self):
        assert self.w_e >= 2 and self.w_f >= 1

    @property
    def nbits(self) -> int:
        return self.w_f + self.w_e + 3

    @property
    def bias(self) -> int:
        return (1 << (self.w_e - 1)) - 1

    @property
    def emax(self) -> int:
        return (1 << self.w_e) - 1  # max biased exponent code

    # Field offsets within the code word (LSB first).
    @property
    def exp_off(self) -> int:
        return self.w_f

    @property
    def sign_off(self) -> int:
        return self.w_f + self.w_e

    @property
    def exc_off(self) -> int:
        return self.w_f + self.w_e + 1

    def mult_out(self, extended: bool = False) -> "FPFormat":
        """Output format of the HOBFLOPS multiplier (paper Table 3):
        single precision keeps w_f+1 fraction bits; extended keeps the
        exact product with 2*w_f+1 fraction bits."""
        return FPFormat(self.w_e, 2 * self.w_f + 1 if extended else self.w_f + 1)

    def max_value(self) -> float:
        return float((2.0 - 2.0 ** -self.w_f) * 2.0 ** (self.emax - self.bias))

    def min_normal(self) -> float:
        return float(2.0 ** (-self.bias))

    def __str__(self) -> str:
        return f"e{self.w_e}m{self.w_f}"


# The evaluated HOBFLOPS family (paper Table 3).  Inputs to the MAC; the
# accumulator runs at fmt.mult_out(extended).
HOBFLOPS_FORMATS: dict[str, FPFormat] = {
    "hobflops_ieee8": FPFormat(4, 3),   # Minifloat / IEEE-style FP8
    "hobflops8": FPFormat(5, 2),        # == MS-FP8
    "hobflops9": FPFormat(5, 3),        # == MS-FP9
    "hobflops10": FPFormat(5, 4),
    "hobflops11": FPFormat(5, 5),
    "hobflops12": FPFormat(5, 6),
    "hobflops13": FPFormat(5, 7),
    "hobflops14": FPFormat(5, 8),
    "hobflops15": FPFormat(5, 9),
    "hobflops16": FPFormat(5, 10),      # IEEE-FP16-shaped (no subnormals)
    "bfloat16": FPFormat(8, 7),         # beyond-paper: bf16-shaped custom FP
}


@dataclasses.dataclass(frozen=True, order=True)
class StorageFormat:
    """Exception-free storage layout for HOBFLOPS-quantized weights.

    Weights are finite, so the 2-bit FloPoCo exception field is dropped
    for storage: ``code = frac | exp << w_f | sign << (w_e + w_f)``,
    with code == 0 meaning exactly zero (the point +2^-bias with frac 0
    is nudged to frac 1 at quantization time).  nbits = 1 + w_e + w_f;
    the bitplane layout stores exactly nbits bits per weight in HBM.
    """
    w_e: int
    w_f: int

    @property
    def nbits(self) -> int:
        return 1 + self.w_e + self.w_f

    @property
    def bias(self) -> int:
        return (1 << (self.w_e - 1)) - 1

    @property
    def emax(self) -> int:
        return (1 << self.w_e) - 1

    @property
    def compute(self) -> FPFormat:
        return FPFormat(self.w_e, self.w_f)

    def container(self) -> str:
        """Narrowest native dtype holding one code ('int8'/'int16')."""
        return "int8" if self.nbits <= 8 else "int16"

    def __str__(self) -> str:
        return f"s_e{self.w_e}m{self.w_f}"


def parse_format(name: str) -> FPFormat:
    """Accepts 'hobflops9', 'e5m3', or 'fp16'-style names."""
    name = name.lower()
    if name in HOBFLOPS_FORMATS:
        return HOBFLOPS_FORMATS[name]
    if name.startswith("e") and "m" in name:
        we, wf = name[1:].split("m")
        return FPFormat(int(we), int(wf))
    raise ValueError(f"unknown FP format {name!r}")
