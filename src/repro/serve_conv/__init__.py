"""Lane-batched serving of HOBFLOPS CNN graphs (DESIGN.md §10).

The bitslice carrier's pixel-row axis is the batch axis, so concurrent
requests pack into one wave that pays a single encode/decode and keeps
the paper's "very wide vectorized" datapath full.  Pieces:

* ``lanes``    — wave packer/unpacker with per-request slot bookkeeping
* ``engine``   — :class:`ConvServeEngine`: queue, wave admission,
                 batch buckets, throughput/latency/occupancy counters
* ``cache``    — compiled-runner cache + ``tune_conv_blocks`` disk
                 persistence
* ``sharding`` — optional multi-device wave sharding over a 1-D mesh
"""
from repro.serve_conv.cache import (RunnerCache, bucket_for, bucket_sizes,
                                    load_tune_cache, save_tune_cache,
                                    tune_cache_path, tuned_conv_blocks)
from repro.serve_conv.engine import (ConvRequest, ConvServeEngine,
                                     derive_max_batch)
from repro.serve_conv.lanes import (WavePlan, WaveSlot, pack_wave,
                                    request_images, unpack_wave)
from repro.serve_conv.sharding import wave_mesh, wave_sharded_runner

__all__ = [
    "ConvRequest", "ConvServeEngine", "RunnerCache", "WavePlan",
    "WaveSlot", "bucket_for", "bucket_sizes", "derive_max_batch",
    "load_tune_cache", "pack_wave", "request_images", "save_tune_cache",
    "tune_cache_path", "tuned_conv_blocks", "unpack_wave", "wave_mesh",
    "wave_sharded_runner",
]
