"""Lane-batched serving of HOBFLOPS CNN graphs (DESIGN.md §10-§11).

The bitslice carrier's pixel-row axis is the batch axis, so concurrent
requests pack into one wave that pays a single encode/decode and keeps
the paper's "very wide vectorized" datapath full.  Pieces:

* ``lanes``    — wave packer/unpacker with per-request slot bookkeeping
* ``engine``   — :class:`ConvServeEngine` = :class:`WaveScheduler`
                 (bounded queue, deadline-or-full admission, per-request
                 deadlines) + :class:`WaveExecutor` (retry/backoff,
                 bad-runner eviction, straggler observation)
* ``policy``   — :class:`ServePolicy` knobs and the precision-degrading
                 :class:`OverloadController` hysteresis ladder
* ``errors``   — the typed ``ServeError`` taxonomy + request validation
* ``faults``   — chaos layer: injected compile/wave failures,
                 stragglers, corrupted caches (tests + CI chaos job)
* ``cache``    — compiled-runner cache (evictable) + corruption-tolerant
                 ``tune_conv_blocks`` disk persistence
* ``sharding`` — optional multi-device wave sharding over a 1-D mesh
"""
from repro.serve_conv.cache import (RunnerCache, bucket_for, bucket_sizes,
                                    load_tune_cache, save_tune_cache,
                                    tune_cache_path, tuned_conv_blocks)
from repro.serve_conv.engine import (ConvRequest, ConvServeEngine,
                                     WaveExecutor, WaveScheduler,
                                     derive_max_batch)
from repro.serve_conv.errors import (DeadlineExceededError, QueueFullError,
                                     RequestValidationError, ServeError,
                                     WaveExecutionError, WaveShardingError,
                                     validate_request_image)
from repro.serve_conv.faults import (FaultInjector, FaultPlan,
                                     InjectedCompileError, InjectedFault,
                                     InjectedWaveError, chaos_seed,
                                     corrupt_runner_cache,
                                     corrupt_tune_cache)
from repro.serve_conv.lanes import (WavePlan, WaveSlot, pack_wave,
                                    request_images, unpack_wave)
from repro.serve_conv.policy import OverloadController, ServePolicy
from repro.serve_conv.sharding import wave_mesh, wave_sharded_runner

__all__ = [
    "ConvRequest", "ConvServeEngine", "DeadlineExceededError",
    "FaultInjector", "FaultPlan", "InjectedCompileError", "InjectedFault",
    "InjectedWaveError", "OverloadController", "QueueFullError",
    "RequestValidationError", "RunnerCache", "ServeError", "ServePolicy",
    "WaveExecutionError", "WaveExecutor", "WavePlan", "WaveScheduler",
    "WaveShardingError", "WaveSlot", "bucket_for", "bucket_sizes",
    "chaos_seed", "corrupt_runner_cache", "corrupt_tune_cache",
    "derive_max_batch", "load_tune_cache", "pack_wave", "request_images",
    "save_tune_cache", "tune_cache_path", "tuned_conv_blocks",
    "unpack_wave", "validate_request_image", "wave_mesh",
    "wave_sharded_runner",
]
