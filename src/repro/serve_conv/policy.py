"""Admission policy and precision-degrading overload control.

The HOBFLOPS pitch is that precision is a *dial* (hobflops9 runs far
cheaper than hobflops16), and the related work (Fixflow, arXiv
2302.09564; Lai et al., arXiv 1703.03073) frames precision as an
accuracy/cost trade-off to be managed — which makes precision the
natural graceful-degradation axis for an overloaded serving engine:
when the queue backs up, *shed precision before shedding requests*.

Two pieces:

* :class:`ServePolicy` — the engine's declarative knobs: how long a
  partial wave may wait (``wave_deadline_ms``), how deep the queue may
  grow (``max_queue_images``), the default per-request deadline, the
  wave retry budget, and the overload thresholds.
* :class:`OverloadController` — a hysteresis ladder over registered
  precision levels (0 = full precision, rising = cheaper).  Pressure
  is the queued backlog measured in waves (``queued images /
  max_batch``).  Sustained pressure above ``degrade_queue_factor`` for
  ``degrade_patience`` consecutive observations steps one level down
  the ladder; sustained pressure at or below ``recover_queue_factor``
  for ``recover_patience`` observations steps back up.  Patience on
  both edges prevents flapping on a single bursty wave; the recover
  threshold sits below the degrade threshold for the same reason.

Degraded waves run a *pre-registered* cheaper-precision
``NetworkGraph`` variant (built with the §9 mixed-precision machinery,
e.g. ``NetworkGraph.with_precision``) — and remain bit-identical to
``graph.run`` *at that precision*, so the repo's cross-cutting
bit-exactness invariant survives overload: every response is tagged
with the precision level that served it and is exactly what that
graph would have produced for the request alone.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ServePolicy:
    """Declarative serving-robustness knobs for :class:`ConvServeEngine`.

    ``wave_deadline_ms``
        Close a partially-filled wave once the *oldest* queued request
        has waited this long (the classic throughput/latency dial).
        ``None`` keeps the legacy behaviour: any non-empty queue is
        ready, and waves close on fullness or drain.
    ``max_queue_images``
        Bounded queue: ``submit()`` raises :class:`QueueFullError` once
        this many images are queued.  ``None`` = unbounded.
    ``request_timeout_ms``
        Default per-request deadline (a request's own ``deadline_ms``
        overrides it).  Requests that age past it while queued are
        marked with :class:`DeadlineExceededError` and dropped at
        admission.  ``None`` = no deadline.
    ``max_wave_retries`` / ``retry_backoff_s`` / ``backoff_multiplier``
        A failed wave execution is retried up to ``max_wave_retries``
        times with exponential backoff, evicting the (possibly bad)
        cached runner before each retry.  Only after the budget is
        exhausted are the wave's requests quarantined.
    ``degrade_queue_factor`` / ``recover_queue_factor``
        Overload thresholds in units of waves of backlog (queued
        images / max_batch).  ``degrade_queue_factor=None`` disables
        overload control even when degraded variants are registered.
        ``recover_queue_factor`` defaults to half the degrade factor.
    ``degrade_patience`` / ``recover_patience``
        Consecutive pressure observations (one per admission attempt)
        required to move down / up the precision ladder.
    """
    wave_deadline_ms: float | None = None
    max_queue_images: int | None = None
    request_timeout_ms: float | None = None
    max_wave_retries: int = 2
    retry_backoff_s: float = 0.01
    backoff_multiplier: float = 2.0
    degrade_queue_factor: float | None = 2.0
    recover_queue_factor: float | None = None
    degrade_patience: int = 3
    recover_patience: int = 3

    def recover_threshold(self) -> float:
        if self.recover_queue_factor is not None:
            return self.recover_queue_factor
        return (self.degrade_queue_factor or 0.0) / 2.0


class OverloadController:
    """Hysteresis ladder over precision levels ``0 .. levels-1``.

    ``observe(pressure)`` is called once per admission attempt and
    returns the level the next wave should serve at.  ``activations``
    counts downward steps (degradations) for the stats surface and the
    load benchmark; ``transitions`` records ``(wave_index_hint,
    from_level, to_level)`` tuples for post-hoc inspection.
    """

    def __init__(self, levels: int, policy: ServePolicy):
        assert levels >= 1
        self.levels = levels
        self.policy = policy
        self.level = 0
        self.activations = 0
        self.transitions: list[tuple[int, int, int]] = []
        self._hot = 0
        self._cold = 0
        self._observations = 0

    def observe(self, pressure: float) -> int:
        """Update the hot/cold streaks with one pressure sample and
        return the (possibly changed) serving level."""
        self._observations += 1
        if self.policy.degrade_queue_factor is None or self.levels == 1:
            return self.level
        if pressure > self.policy.degrade_queue_factor:
            self._hot += 1
            self._cold = 0
        elif pressure <= self.policy.recover_threshold():
            self._cold += 1
            self._hot = 0
        else:                       # between thresholds: streaks decay
            self._hot = 0
            self._cold = 0
        if self._hot >= self.policy.degrade_patience \
                and self.level < self.levels - 1:
            self.transitions.append((self._observations, self.level,
                                     self.level + 1))
            self.level += 1
            self.activations += 1
            self._hot = 0
        elif self._cold >= self.policy.recover_patience and self.level > 0:
            self.transitions.append((self._observations, self.level,
                                     self.level - 1))
            self.level -= 1
            self._cold = 0
        return self.level

    def stats(self) -> dict:
        return {"level": self.level, "levels": self.levels,
                "activations": self.activations,
                "transitions": len(self.transitions)}
