"""Lane packing: coalesce queued images into one wave batch.

The HOBFLOPS activation carrier stores a wave as ``[nbits, P, Mw]``
planes with ``P = B*H*W`` pixel rows and channels along int32 lanes
(DESIGN.md §8) — so the *batch axis is the bitslice row axis*, and the
marginal cost of an extra image in a wave is just more rows through the
same plane-wide netlists.  Serving one image at a time leaves that
width idle; the packer here coalesces N queued requests (possibly
heterogeneous image counts, same HxWxC per engine instance) into one
stacked NHWC batch, padded up to the wave's compiled batch bucket with
all-zero images, with per-request slot bookkeeping so each result is
sliced back out bit-exactly.

Bit-exactness of the whole scheme rests on the fact that every plane
op is elementwise per pixel row (MAC netlists, casts, ReLU) or combines
rows only *within* one image of the batch (``window_gather_planes``
and the im2col both restore the NHWC structure before gathering, so
windows never straddle the batch axis).  A request's rows therefore
compute the same codes whether it rides alone or packed in a wave —
the serve tests assert this bit-for-bit, pad images included.

``stack_requests``/``split_wave`` also exist at the plane level
(``core.bitslice.stack_activations``/``split_activation``) for callers
that hold pre-encoded :class:`BitsliceActivation` carriers.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.serve_conv.errors import RequestValidationError, ServeError


@dataclasses.dataclass(frozen=True)
class WaveSlot:
    """Where one request's images live inside a packed wave."""
    start: int            # first image index in the wave batch
    count: int            # images this request contributed
    squeeze: bool         # request was a single [H,W,C] image (no batch
                          # dim); unpack restores the original rank


@dataclasses.dataclass(frozen=True)
class WavePlan:
    """A packed wave: the stacked batch geometry plus per-request
    slots.  ``bucket - filled`` trailing images are all-zero pad."""
    slots: tuple[WaveSlot, ...]
    bucket: int

    @property
    def filled(self) -> int:
        return sum(s.count for s in self.slots)

    @property
    def occupancy(self) -> float:
        """Fraction of the wave's batch slots carrying real images —
        the lane-occupancy counter the engine aggregates."""
        return self.filled / self.bucket


def request_images(image) -> int:
    """Image count a request contributes: 1 for [H,W,C], B for
    [B,H,W,C]."""
    nd = np.ndim(image)
    if nd == 3:
        return 1
    if nd == 4:
        return int(np.shape(image)[0])
    raise RequestValidationError(
        f"request image must be [H,W,C] or [B,H,W,C], got rank {nd}")


def pack_wave(images, bucket: int, hwc=None):
    """Stack per-request images into one ``[bucket, H, W, C]`` f32
    batch.

    ``images`` is a sequence of [H,W,C] or [B,H,W,C] float arrays, all
    sharing (H, W, C) (validated against ``hwc`` when given).  Requests
    are laid out contiguously in submission order; slack up to
    ``bucket`` is zero images (the +0 code in every plane — dead rows
    the slots never read back).  Returns ``(batch, WavePlan)``.
    """
    if not images:
        raise ServeError("pack_wave: empty wave")
    slots, parts, off = [], [], 0
    for img in images:
        request_images(img)        # the single rank-contract check
        arr = np.asarray(img, dtype=np.float32)
        squeeze = arr.ndim == 3
        if squeeze:
            arr = arr[None]
        if hwc is None:
            hwc = arr.shape[1:]
        elif arr.shape[1:] != tuple(hwc):
            raise RequestValidationError(
                f"request geometry {arr.shape[1:]} != engine geometry "
                f"{tuple(hwc)} (one engine instance serves one HxWxC)")
        slots.append(WaveSlot(off, arr.shape[0], squeeze))
        parts.append(arr)
        off += arr.shape[0]
    if off > bucket:
        raise ServeError(
            f"wave holds {off} images but the bucket is {bucket}")
    if off < bucket:
        parts.append(np.zeros((bucket - off,) + tuple(hwc), np.float32))
    return np.concatenate(parts, axis=0), WavePlan(tuple(slots), bucket)


def unpack_wave(out, plan: WavePlan):
    """Slice a wave output ``[bucket, Ho, Wo, M]`` back into
    per-request results (restoring [Ho,Wo,M] rank for single-image
    requests).  Pure slicing — bit-exact by construction."""
    results = []
    for s in plan.slots:
        r = out[s.start:s.start + s.count]
        results.append(r[0] if s.squeeze else r)
    return results
