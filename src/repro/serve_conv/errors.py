"""Typed error taxonomy for the lane-batched serving engine.

The original engine failed deep: a bad request died as an assert inside
``pack_wave`` (poisoning the whole wave), a failed jit compile unwound
through ``run_wave`` with the queue half-popped, and queue growth was
unbounded.  Serving robustness starts with *names* for the ways serving
fails, raised at the earliest boundary that can detect them:

``ServeError``
    Base of everything the engine raises on purpose.  Anything else
    escaping a wave is a defect (or injected chaos) and is converted to
    :class:`WaveExecutionError` by the executor's retry loop.

``RequestValidationError``
    The request itself is unservable — wrong rank/geometry, non-float
    dtype, NaN/Inf payload.  Raised by ``submit()`` *before* the
    request enters the queue, so a bad request can never poison a wave.
    Subclasses ``ValueError`` so pre-taxonomy callers that caught
    ``ValueError`` keep working.

``QueueFullError``
    Bounded-queue admission control: the queue already holds
    ``max_queue_images`` images.  Shedding at submit keeps latency
    bounded instead of letting the backlog (and every queued request's
    deadline miss) grow without limit.

``DeadlineExceededError``
    A queued request aged past its deadline before a wave could take
    it.  Recorded on the request (``req.error``), not raised — the
    submitter already got their synchronous ``submit()`` back.

``WaveExecutionError``
    A wave failed after the executor exhausted its retry budget.  Also
    recorded on each quarantined request rather than raised, so one
    poisoned wave cannot take the engine down: the engine keeps
    admitting and serving subsequent waves.

``WaveShardingError``
    A wave batch that cannot split over the configured device mesh —
    an engine-configuration bug, surfaced with the mesh arithmetic.
"""
from __future__ import annotations

import numpy as np


class ServeError(Exception):
    """Base class for every intentional serving-path failure."""


class RequestValidationError(ServeError, ValueError):
    """The request payload is unservable (shape/dtype/NaN/Inf)."""


class QueueFullError(ServeError):
    """Bounded queue is full; the request was shed at submit()."""


class DeadlineExceededError(ServeError):
    """The request aged out of its deadline while queued."""


class WaveExecutionError(ServeError):
    """A wave failed after the retry budget; its requests are
    quarantined.  ``attempts`` counts executions tried; ``__cause__``
    holds the last underlying error."""

    def __init__(self, msg: str, attempts: int = 1):
        super().__init__(msg)
        self.attempts = attempts


class WaveShardingError(ServeError, ValueError):
    """A wave batch that does not divide over the device mesh."""


def validate_request_image(image, hwc=None, *,
                           max_images: int | None = None) -> int:
    """Admission-time payload validation; returns the image count.

    Checks — each a :class:`RequestValidationError` naming the defect —
    in order: rank is 3 ([H,W,C]) or 4 ([B,H,W,C]); dtype is a real
    float (codes for int payloads would be garbage, not a quantization);
    geometry matches the engine's ``hwc``; image count fits
    ``max_images``; every element is finite (NaN/Inf would encode to
    exception codes and quietly propagate through every downstream
    netlist of the wave).
    """
    arr = np.asarray(image)
    if arr.ndim not in (3, 4):
        raise RequestValidationError(
            f"request image must be [H,W,C] or [B,H,W,C], got rank "
            f"{arr.ndim} (shape {arr.shape})")
    if not np.issubdtype(arr.dtype, np.floating):
        raise RequestValidationError(
            f"request image dtype {arr.dtype} is not a float type")
    if hwc is not None and arr.shape[-3:] != tuple(hwc):
        raise RequestValidationError(
            f"request geometry {arr.shape[-3:]} != engine geometry "
            f"{tuple(hwc)} (one engine instance serves one HxWxC)")
    n = 1 if arr.ndim == 3 else int(arr.shape[0])
    if max_images is not None and n > max_images:
        raise RequestValidationError(
            f"request carries {n} images > max_batch {max_images}; "
            f"split it across requests")
    if not np.isfinite(arr).all():
        bad = int(arr.size - np.isfinite(arr).sum())
        raise RequestValidationError(
            f"request payload holds {bad} non-finite element(s) "
            f"(NaN/Inf); rejected before it can poison a wave")
    return n
