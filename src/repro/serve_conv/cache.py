"""Compilation and autotune caches for the lane-batched serve engine.

Two cold-start costs dominate a fresh HOBFLOPS serving process: jit
compilation of the resident graph runner (one XLA program per input
shape) and the ``tune_conv_blocks`` sweep (dozens of end-to-end timed
launches).  Both are pure functions of static structure, so both cache:

* :class:`RunnerCache` — compiled wave runners keyed by
  ``(graph signature, input HxWxC, batch bucket, precision plan)``.
  Wave sizes are rounded up to power-of-two *buckets* (1/2/4/...), so a
  handful of compilations serves every traffic mix; the tail of a
  ragged final wave rides as zero-image pad instead of forcing a fresh
  shape.  Entries hold the graph's bare compiled entrypoint
  (``NetworkGraph.resident_runner``), with the bucket's shape validated
  through ``shape_plan`` exactly once, on miss.
* Tune persistence — ``tuned_conv_blocks`` wraps ``tune_conv_blocks``
  with a JSON disk cache keyed by the problem signature (shapes,
  kernel geometry, format, stride/padding, backend), so repeat
  processes skip the sweep entirely.  The path defaults to
  ``.hobflops_tune.json`` in the working directory and is overridden
  by the ``HOBFLOPS_TUNE_CACHE`` environment variable or an explicit
  argument.
"""
from __future__ import annotations

import dataclasses
import json
import os
import warnings

from repro.kernels.conv2d_bitslice.network import NetworkGraph
from repro.kernels.conv2d_bitslice.ops import ConvWeights, tune_conv_blocks

TUNE_CACHE_ENV = "HOBFLOPS_TUNE_CACHE"
_TUNE_CACHE_DEFAULT = ".hobflops_tune.json"


# ---------------------------------------------------------------------------
# Batch buckets
# ---------------------------------------------------------------------------
def bucket_sizes(max_batch: int) -> tuple[int, ...]:
    """The power-of-two bucket ladder up to and including
    ``max_batch`` (itself appended if not a power of two)."""
    assert max_batch >= 1
    sizes = []
    b = 1
    while b < max_batch:
        sizes.append(b)
        b *= 2
    sizes.append(max_batch)
    return tuple(sizes)


def bucket_for(n: int, buckets) -> int:
    """Smallest bucket holding ``n`` images."""
    for b in buckets:
        if n <= b:
            return b
    raise ValueError(f"{n} images exceed the largest bucket "
                     f"{max(buckets)}")


# ---------------------------------------------------------------------------
# Compiled-runner cache
# ---------------------------------------------------------------------------
class RunnerCache:
    """Wave runners keyed by (graph signature, HxWxC, bucket,
    precision plan).

    The jit cache inside jax already memoizes per shape; this layer
    exists to (a) make the compilation *policy* explicit — only bucket
    shapes ever reach jit, so the program count is bounded by the
    bucket ladder — and (b) count hits/misses/evictions so the
    engine's stats expose cold-start and self-healing behaviour.  One
    cache may serve several engines (or several graphs) at once.
    Entries are never evicted for capacity (a serving process holds a
    handful of buckets by construction) but the executor evicts an
    entry whose wave *failed* — a corrupted/bad runner can only be
    cured by rebuild, and the next ``get`` re-misses cleanly.
    """

    def __init__(self):
        self._runners: dict[tuple, object] = {}
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._runners)

    def keys(self) -> tuple:
        return tuple(self._runners)

    def evict(self, key) -> bool:
        """Drop one cached runner (the executor's bad-runner path);
        True if the key was present."""
        if key in self._runners:
            del self._runners[key]
            self.evictions += 1
            return True
        return False

    def replace(self, key, fn):
        """Swap a cached runner in place — the chaos layer's seam for
        corrupting a live entry (``faults.corrupt_runner_cache``)."""
        assert key in self._runners, key
        self._runners[key] = fn

    def key(self, graph: NetworkGraph, hwc, bucket: int,
            variant: str = "local") -> tuple:
        # The precision plan rides inside signature() (every node's
        # format is part of the hashed compiled structure), so the key
        # needs no second notion of precision identity.
        return (graph.signature(), tuple(hwc), int(bucket), variant)

    def get(self, graph: NetworkGraph, hwc, bucket: int, *,
            build=None, variant: str = "local"):
        """The compiled wave entrypoint for this (graph, geometry,
        bucket) — built (and its bucket shape validated) on miss.
        ``build`` overrides how the runner is constructed (the engine
        passes the mesh-sharded builder, with a matching ``variant``
        so local and sharded runners never collide)."""
        key = self.key(graph, hwc, bucket, variant)
        fn = self._runners.get(key)
        if fn is None:
            self.misses += 1
            graph.shape_plan((bucket,) + tuple(hwc))
            fn = build() if build is not None else graph.resident_runner()
            self._runners[key] = fn
        else:
            self.hits += 1
        return fn


# ---------------------------------------------------------------------------
# tune_conv_blocks persistence
# ---------------------------------------------------------------------------
def tune_cache_path(path: str | None = None) -> str:
    """Explicit argument > ``HOBFLOPS_TUNE_CACHE`` env var > cwd
    default."""
    return path or os.environ.get(TUNE_CACHE_ENV) or _TUNE_CACHE_DEFAULT


def load_tune_cache(path: str | None = None) -> dict:
    """Load the tune cache, tolerating a corrupted/truncated file.

    The cache is an *accelerator*, never a correctness input, so a
    file torn by a killed process or a bad disk must degrade to "no
    cache" — warn (so operators see the lost winners), ignore the
    content, and let the next :func:`save_tune_cache` rebuild the file
    atomically (it merges from this loader, so a corrupt file merges
    as empty and is simply replaced wholesale).  A parseable file with
    a non-dict top level is corrupt too.
    """
    p = tune_cache_path(path)
    if not os.path.exists(p):
        return {}
    try:
        with open(p) as f:
            cache = json.load(f)
        if not isinstance(cache, dict):
            raise ValueError(
                f"top-level JSON is {type(cache).__name__}, not object")
    except (OSError, ValueError) as e:   # unreadable/corrupt: retune
        warnings.warn(
            f"tune cache {p!r} is corrupt or unreadable ({e}); "
            f"ignoring it — sweeps will re-run and the next save "
            f"rewrites the file atomically", RuntimeWarning,
            stacklevel=2)
        return {}
    return cache


def save_tune_cache(cache: dict, path: str | None = None) -> str:
    """Merge ``cache`` into the file and replace it atomically: the
    on-disk entries are re-read and merged first (so two processes
    tuning *different* problems don't drop each other's winners — the
    remaining same-key race just rewrites an equivalent winner), and
    the write goes through a temp file + ``os.replace`` so a killed
    process never leaves a torn JSON behind."""
    p = tune_cache_path(path)
    merged = {**load_tune_cache(path), **cache}
    tmp = f"{p}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(merged, f, indent=2, sort_keys=True)
        f.write("\n")
    os.replace(tmp, p)
    return p


def tune_key(images_shape, kernels, fmt, *, backend: str = "jnp",
             candidates=None, **conv_kw) -> str:
    """Problem signature for one tuned conv: everything that affects
    which launch configuration wins — shapes, kernel geometry, format,
    stride/padding, backend, and the candidate set searched (a
    restricted quick sweep must not answer for the full default
    sweep) — and nothing that doesn't (weight values, timing iters)."""
    if isinstance(kernels, ConvWeights):
        geom = (kernels.kh, kernels.kw, kernels.cin, kernels.cout)
    else:
        geom = tuple(kernels.shape)
    cand = "default" if candidates is None else sorted(
        repr(tuple(sorted(c.items()))) for c in candidates)
    return repr((tuple(images_shape), geom, (fmt.w_e, fmt.w_f), backend,
                 conv_kw.get("stride", 1), conv_kw.get("padding", "SAME"),
                 conv_kw.get("extended", False), cand))


def tuned_conv_blocks(images, kernels, *, fmt, backend: str = "jnp",
                      path: str | None = None, **tune_kw):
    """``tune_conv_blocks`` with a JSON disk cache.

    On a cache hit the stored block dict is returned without running a
    single candidate (a seeded cache is honored verbatim — tests rely
    on this); on a miss the sweep runs and its winner is persisted.
    Returns ``(blocks, seconds_per_call_or_None)`` — the timing is None
    on a hit (it was measured on some earlier process/machine and is
    kept only as a provenance hint in the file).

    Entries are versioned with the backend they were tuned for.  A
    winner tuned for the gate-interpreter backend is not a winner for
    the fused kernel, so an entry written before backends were tagged
    (or hand-seeded without a tag) is treated as *stale*: it is never
    reused silently — a warning names the entry and the sweep re-runs,
    overwriting it with a tagged winner.
    """
    key = tune_key(images.shape, kernels, fmt, backend=backend,
                   candidates=tune_kw.get("candidates"),
                   **{k: v for k, v in tune_kw.items()
                      if k in ("stride", "padding", "extended")})
    hit = load_tune_cache(path).get(key)
    if hit is not None:
        if hit.get("backend") == backend:
            return dict(hit["blocks"]), None
        tag = ("untagged (pre-backend-versioning)"
               if "backend" not in hit
               else f"tuned for backend {hit['backend']!r}")
        warnings.warn(
            f"tune cache entry for this problem is stale — {tag}, but "
            f"backend {backend!r} was requested; retuning instead of "
            f"reusing it (the fresh winner replaces the entry)",
            RuntimeWarning, stacklevel=2)
    best, results = tune_conv_blocks(images, kernels, fmt=fmt,
                                     backend=backend, **tune_kw)
    save_tune_cache({key: {"blocks": best, "backend": backend,
                           "seconds_per_call": min(results.values())}},
                    path)
    return best, min(results.values())
