"""Fault injection for the serving path (chaos layer).

A robustness claim that was never exercised is a guess.  This module
injects the failure modes the engine promises to survive, at the same
seams where the real ones occur, so ``tests/test_serve_faults.py`` (and
the CI chaos smoke job) can prove the self-healing loop end-to-end:

* **Runner compile failures** — ``on_build`` raises
  :class:`InjectedCompileError` for the next ``compile_failures``
  runner builds, standing in for an XLA lowering/compile error.  The
  executor's retry loop must rebuild and the request must still be
  answered bit-exactly.
* **Transient wave-execution errors** — ``wrap_runner`` raises
  :class:`InjectedWaveError` for the next ``wave_errors`` wave
  executions (a transient device/launch failure).  The retry loop must
  re-execute the identical wave.
* **Artificial stragglers** — the next ``straggle_waves`` wave
  executions sleep ``straggle_s`` before running, so the
  :class:`~repro.ft.straggler.StragglerMonitor` wired into the engine
  sees a genuinely slow wave class and flags it in ``stats()``.
* **Corrupted runner-cache entries** — :func:`corrupt_runner_cache`
  replaces cached compiled runners with poison callables that always
  raise, standing in for a cache entry gone stale/invalid underneath a
  live engine.  The engine must *evict* the bad entry (not just retry
  it) and rebuild.
* **Corrupted tune cache** — :func:`corrupt_tune_cache` truncates the
  ``tuned_conv_blocks`` JSON file mid-token; ``load_tune_cache`` must
  warn, ignore, and let the next save rebuild the file atomically.

All injection is deterministic: counters tick down in call order, and
the only randomness (picking which cache entries to poison) draws from
a seeded generator (``HOBFLOPS_CHAOS_SEED``, default 0) so the CI
chaos job replays identically.

Injected errors deliberately do **not** subclass ``ServeError``: from
the engine's perspective they are the *unknown* failures robustness is
for, and the executor must translate them into the typed taxonomy.
"""
from __future__ import annotations

import dataclasses
import os
import time

import numpy as np

CHAOS_SEED_ENV = "HOBFLOPS_CHAOS_SEED"


def chaos_seed(default: int = 0) -> int:
    """The fixed chaos seed: ``HOBFLOPS_CHAOS_SEED`` env override (the
    CI chaos job pins it) else ``default``."""
    try:
        return int(os.environ.get(CHAOS_SEED_ENV, default))
    except ValueError:
        return default


class InjectedFault(RuntimeError):
    """Marker base for chaos-injected failures (NOT a ServeError: the
    engine must treat these as unknown infrastructure errors)."""


class InjectedCompileError(InjectedFault):
    """Stands in for a jit/XLA compile failure during runner build."""


class InjectedWaveError(InjectedFault):
    """Stands in for a transient device error during wave execution."""


@dataclasses.dataclass
class FaultPlan:
    """Mutable injection budget; counters tick down as faults fire.
    A test (or the chaos job) sets the budget, runs traffic, and then
    asserts both that the faults fired (counters at zero, injector
    tallies up) and that every answer stayed bit-exact."""
    compile_failures: int = 0     # next N runner builds raise
    wave_errors: int = 0          # next N wave executions raise
    straggle_waves: int = 0       # next N wave executions sleep first
    straggle_s: float = 0.05


class FaultInjector:
    """The chaos seams the executor threads its build/execute calls
    through.  With an all-zero :class:`FaultPlan` every hook is a
    no-op, so production engines simply pass ``faults=None``."""

    def __init__(self, plan: FaultPlan | None = None, *,
                 seed: int | None = None, sleep=time.sleep):
        self.plan = plan or FaultPlan()
        self.rng = np.random.default_rng(
            chaos_seed() if seed is None else seed)
        self._sleep = sleep
        self.injected_compile_failures = 0
        self.injected_wave_errors = 0
        self.injected_straggles = 0

    # -- seams -------------------------------------------------------------
    def on_build(self):
        """Called by the executor immediately before a runner build."""
        if self.plan.compile_failures > 0:
            self.plan.compile_failures -= 1
            self.injected_compile_failures += 1
            raise InjectedCompileError(
                "injected: runner compile failure")

    def wrap_runner(self, fn):
        """Wrap a compiled wave runner with the wave-level faults
        (straggle, then transient error) — checked per *execution*, so
        a retried wave re-rolls against the remaining budget."""
        def chaotic_runner(batch):
            if self.plan.straggle_waves > 0:
                self.plan.straggle_waves -= 1
                self.injected_straggles += 1
                self._sleep(self.plan.straggle_s)
            if self.plan.wave_errors > 0:
                self.plan.wave_errors -= 1
                self.injected_wave_errors += 1
                raise InjectedWaveError(
                    "injected: transient wave-execution error")
            return fn(batch)
        return chaotic_runner


# ---------------------------------------------------------------------------
# Cache corruption (operate on state, not call seams)
# ---------------------------------------------------------------------------
def corrupt_runner_cache(cache, n: int | None = None,
                         seed: int | None = None) -> list:
    """Replace ``n`` random cached runners (all by default) with poison
    callables that raise :class:`InjectedWaveError` on every call —
    retrying the same entry can never succeed; only eviction + rebuild
    recovers.  Returns the corrupted keys."""
    keys = list(cache.keys())
    rng = np.random.default_rng(chaos_seed() if seed is None else seed)
    if n is not None and n < len(keys):
        keys = [keys[i] for i in
                sorted(rng.choice(len(keys), size=n, replace=False))]

    def poisoned(batch):
        raise InjectedWaveError("injected: corrupted runner-cache entry")

    for k in keys:
        cache.replace(k, poisoned)
    return keys


def corrupt_tune_cache(path: str) -> str:
    """Truncate the tune-cache JSON file mid-token (the torn-write /
    bad-disk case ``load_tune_cache`` must tolerate)."""
    with open(path) as f:
        text = f.read()
    with open(path, "w") as f:
        f.write(text[:max(1, len(text) // 2)].rstrip("}\n ") + '"trunc')
    return path
