"""SLO-aware lane-batched inference engine for HOBFLOPS graphs.

The transformer engine (``serve/engine.py``, DESIGN.md §6) batches
requests into decode *slots* of a lockstep wave; the CNN engine here
exploits the HOBFLOPS-specific fact that the bitslice carrier's
pixel-row axis *is* the batch axis (DESIGN.md §10): N queued images
coalesce into one ``[N,H,W,C]`` wave that runs through the resident
graph as one compiled call — one activation encode, one decode, and
every plane netlist sweeping all N requests' rows at once.

This module is the robust rebuild of that engine (DESIGN.md §11),
split into three cooperating pieces:

* :class:`WaveScheduler` — admission.  A bounded queue with typed
  load-shedding (``QueueFullError``), per-request deadlines (aged-out
  requests are expired at admission, never packed), and
  *deadline-or-full* wave closing: a wave closes when it fills
  ``max_batch`` **or** when the oldest queued request has waited
  ``wave_deadline_ms`` — the throughput/latency dial.  Without a
  deadline the legacy drain behaviour is preserved.
* :class:`WaveExecutor` — execution.  Builds compiled runners through
  the :class:`RunnerCache`, executes waves with bounded
  retry-with-backoff, evicts possibly-bad cached runners before every
  retry (the only cure for a corrupted cache entry), validates the
  output shape (a garbage-shaped result is a failure, not an answer),
  and feeds per-bucket wave times to a
  :class:`~repro.ft.straggler.StragglerMonitor`.  All chaos seams
  (``faults.py``) thread through here.
* :class:`ConvServeEngine` — the composition.  Validates requests at
  ``submit()`` with the typed taxonomy (``errors.py``), runs the
  stepped admission loop, routes overloaded waves to pre-registered
  cheaper-precision graph variants under the
  :class:`~repro.serve_conv.policy.OverloadController` hysteresis
  ladder, quarantines the requests of a wave that failed its whole
  retry budget (the engine keeps serving), tracks p50/p99 end-to-end
  latency, and beats a :class:`~repro.ft.heartbeat.Heartbeat` for
  external liveness probes.

Every *served* response — full precision or degraded, retried or not —
remains bit-identical to ``graph.run`` on that request alone **at the
precision it was served at**, and carries that precision as an
explicit tag (``req.precision``/``req.level``).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque

import jax
import numpy as np

from repro.ft.heartbeat import Heartbeat
from repro.ft.straggler import StragglerMonitor
from repro.kernels.conv2d_bitslice.network import NetworkGraph
from repro.kernels.conv2d_bitslice.ops import derive_blocks
from repro.serve_conv.cache import RunnerCache, bucket_for, bucket_sizes
from repro.serve_conv.errors import (DeadlineExceededError, QueueFullError,
                                     WaveExecutionError,
                                     validate_request_image)
from repro.serve_conv.lanes import pack_wave, request_images, unpack_wave
from repro.serve_conv.policy import OverloadController, ServePolicy
from repro.serve_conv.sharding import mesh_size, wave_sharded_runner


@dataclasses.dataclass
class ConvRequest:
    """One queued inference request: a single [H,W,C] image or a
    [B,H,W,C] mini-batch (heterogeneous counts mix freely in a wave).

    Lifecycle fields the engine fills in: ``status`` moves through
    ``queued -> served | failed | expired``; ``error`` holds the typed
    reason for the two failure states; ``precision``/``level``/
    ``degraded`` tag which registered graph variant served it (level 0
    = full precision); ``latency_s`` is the wave execution time it rode
    in and ``e2e_latency_s`` adds its queue wait."""
    rid: int
    image: np.ndarray
    out: np.ndarray | None = None
    done: bool = False
    wave: int | None = None          # which wave served it
    latency_s: float | None = None   # wave execution time it rode in
    deadline_ms: float | None = None  # per-request deadline override
    submitted_at: float | None = None
    status: str = "queued"
    error: Exception | None = None
    precision: str | None = None     # label of the variant that served it
    level: int | None = None         # ladder level (0 = full precision)
    degraded: bool = False
    attempts: int = 0                # wave executions it took
    e2e_latency_s: float | None = None


def derive_max_batch(hwc, p_block: int = 8, row_budget_blocks: int = 512,
                     cap: int = 64) -> int:
    """Wave admission budget from the tuned row blocking: the largest
    power of two whose wave stays within ``p_block * row_budget_blocks``
    carrier rows (B*H*W), clamped to [1, cap]."""
    h, w, _ = hwc
    budget = max(1, (p_block * row_budget_blocks) // (h * w))
    b = 1
    while b * 2 <= min(budget, cap):
        b *= 2
    return b


# ---------------------------------------------------------------------------
# Scheduler: bounded queue + deadline-or-full wave closing
# ---------------------------------------------------------------------------
class WaveScheduler:
    """Admission state: the bounded request queue and the wave-closing
    decision.  Pure bookkeeping — no jax, no execution — so the policy
    is testable with a fake clock."""

    def __init__(self, max_batch: int, policy: ServePolicy):
        self.max_batch = max_batch
        self.policy = policy
        self.queue: deque[ConvRequest] = deque()
        self.queued_images = 0

    def __len__(self) -> int:
        return len(self.queue)

    def submit(self, req: ConvRequest, n_images: int, now: float):
        """Enqueue or shed: a bounded queue rejects with a typed
        :class:`QueueFullError` instead of growing without limit."""
        cap = self.policy.max_queue_images
        if cap is not None and self.queued_images + n_images > cap:
            raise QueueFullError(
                f"queue holds {self.queued_images} images; request "
                f"{req.rid} (+{n_images}) exceeds max_queue_images "
                f"{cap}")
        req.submitted_at = now
        req.status = "queued"
        self.queue.append(req)
        self.queued_images += n_images

    def pressure(self) -> float:
        """Backlog in waves: queued images / max_batch — the overload
        controller's input signal."""
        return self.queued_images / self.max_batch

    def _deadline_ms(self, req: ConvRequest) -> float | None:
        return req.deadline_ms if req.deadline_ms is not None \
            else self.policy.request_timeout_ms

    def expire(self, now: float) -> list[ConvRequest]:
        """Sweep out requests whose per-request deadline has passed —
        they are marked ``expired`` with a typed error and never reach
        a wave (serving them late helps no one and steals lanes)."""
        expired = []
        keep = deque()
        for req in self.queue:
            dl = self._deadline_ms(req)
            if dl is not None and (now - req.submitted_at) * 1e3 > dl:
                req.status = "expired"
                req.error = DeadlineExceededError(
                    f"request {req.rid} waited "
                    f"{(now - req.submitted_at) * 1e3:.1f}ms > deadline "
                    f"{dl:.1f}ms")
                self.queued_images -= request_images(req.image)
                expired.append(req)
            else:
                keep.append(req)
        self.queue = keep
        return expired

    def oldest_wait_ms(self, now: float) -> float | None:
        if not self.queue:
            return None
        return (now - self.queue[0].submitted_at) * 1e3

    def next_deadline(self) -> float | None:
        """Absolute clock time at which the oldest queued request
        forces the wave closed (None: empty queue or no deadline
        policy).  Lets a driving loop sleep exactly until the next
        admission event instead of polling."""
        if not self.queue or self.policy.wave_deadline_ms is None:
            return None
        return self.queue[0].submitted_at \
            + self.policy.wave_deadline_ms / 1e3

    def wave_ready(self, now: float) -> bool:
        """Deadline-or-full: the wave closes when queued images fill
        ``max_batch`` or the oldest request has waited
        ``wave_deadline_ms``.  With no deadline configured any
        non-empty queue is ready (legacy drain behaviour)."""
        if not self.queue:
            return False
        if self.policy.wave_deadline_ms is None:
            return True
        if self.queued_images >= self.max_batch:
            return True
        return self.oldest_wait_ms(now) >= self.policy.wave_deadline_ms

    def take(self) -> list[ConvRequest]:
        """Pop whole requests while the wave stays within max_batch."""
        wave, filled = [], 0
        while self.queue:
            n = request_images(self.queue[0].image)
            if wave and filled + n > self.max_batch:
                break
            wave.append(self.queue.popleft())
            filled += n
        self.queued_images -= filled
        return wave


# ---------------------------------------------------------------------------
# Executor: build + run waves with retry/backoff, eviction, chaos seams
# ---------------------------------------------------------------------------
class WaveExecutor:
    """Owns everything between "here is a packed wave" and "here are
    its output planes": runner build through the cache, bounded
    retry-with-backoff, bad-runner eviction, output-shape validation,
    and straggler observation.  Raises :class:`WaveExecutionError`
    only after the whole retry budget is spent."""

    def __init__(self, cache: RunnerCache, policy: ServePolicy, *,
                 faults=None, straggler: StragglerMonitor | None = None,
                 sleep=time.sleep):
        self.cache = cache
        self.policy = policy
        self.faults = faults
        self.straggler = straggler
        self._sleep = sleep
        self.retries = 0            # re-executions after a failure
        self.failures = 0           # failed executions (incl. retried)

    def _runner(self, graph: NetworkGraph, hwc, bucket: int, mesh):
        variant = "local" if mesh is None else f"wave{mesh_size(mesh)}"

        def build():
            if self.faults is not None:
                self.faults.on_build()
            if mesh is None:
                return graph.resident_runner()
            return wave_sharded_runner(graph, mesh)

        fn = self.cache.get(graph, hwc, bucket, build=build,
                            variant=variant)
        key = self.cache.key(graph, hwc, bucket, variant)
        return fn, key

    def execute(self, graph: NetworkGraph, hwc, bucket: int, batch,
                out_shape, mesh=None):
        """Run one packed wave; returns ``(out, seconds, attempts)``.

        Each attempt rebuilds/refetches the runner (so an injected or
        real compile failure is retried too), executes, and validates
        the output shape.  Any failure evicts the cached runner for
        this key — a corrupted cache entry can only be cured by
        rebuild — then backs off exponentially before the next try.
        """
        delay = self.policy.retry_backoff_s
        budget = self.policy.max_wave_retries + 1
        last: Exception | None = None
        for attempt in range(1, budget + 1):
            try:
                fn, key = self._runner(graph, hwc, bucket, mesh)
                if self.faults is not None:
                    fn = self.faults.wrap_runner(fn)
                t0 = time.perf_counter()
                out = np.asarray(jax.block_until_ready(fn(batch)))
                dt = time.perf_counter() - t0
                if out.shape != tuple(out_shape):
                    raise RuntimeError(
                        f"wave output shape {out.shape} != expected "
                        f"{tuple(out_shape)} (corrupted runner?)")
                if self.straggler is not None:
                    self.straggler.observe(f"bucket{bucket}", dt)
                return out, dt, attempt
            except Exception as e:  # noqa: BLE001 — the executor is the
                # translation boundary: unknown infrastructure errors
                # (and injected chaos) become the typed taxonomy here.
                last = e
                self.failures += 1
                self.cache.evict(
                    self.cache.key(graph, hwc, bucket,
                                   "local" if mesh is None
                                   else f"wave{mesh_size(mesh)}"))
                if attempt < budget:
                    self.retries += 1
                    self._sleep(delay)
                    delay *= self.policy.backoff_multiplier
        raise WaveExecutionError(
            f"wave failed after {budget} attempt(s): {last!r}",
            attempts=budget) from last


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------
class ConvServeEngine:
    """SLO-aware wave-scheduled serving of a frozen
    :class:`NetworkGraph` (plus optional cheaper-precision variants)
    at one input geometry.

    >>> eng = ConvServeEngine(graph, (H, W, C),
    ...                       policy=ServePolicy(wave_deadline_ms=5.0))
    >>> eng.register_degraded(graph.with_precision(fmt8), "hobflops8")
    >>> eng.submit(ConvRequest(0, img))
    >>> done = eng.run()          # or eng.step() in a serving loop
    >>> eng.stats()["p99_latency_ms"], done[0].precision

    Every served request's output is bit-identical to ``graph.run`` on
    that request alone *at the precision it was served at* — packing,
    bucket pad, sharding, retries, and degradation never change a
    single code (tests assert it)."""

    def __init__(self, graph: NetworkGraph, hwc, *,
                 max_batch: int | None = None, blocks: dict | None = None,
                 mesh=None, runner_cache: RunnerCache | None = None,
                 policy: ServePolicy | None = None, faults=None,
                 heartbeat_dir: str | None = None,
                 heartbeat_host: str = "serve0",
                 clock=time.monotonic, verbose: bool = False):
        assert graph._out is not None, "freeze the graph (output()) first"
        self.graph = graph
        self.hwc = tuple(hwc)
        self.policy = policy or ServePolicy()
        self.clock = clock
        h, w, c = self.hwc
        # tuned block dicts carry only the swept keys (missing ones mean
        # "use the derived default", same as the kernel launch)
        p_block = (blocks or {}).get("p_block") \
            or derive_blocks(h * w, 1, 1)["p_block"]
        self.max_batch = max_batch or derive_max_batch(self.hwc, p_block)
        self.mesh = mesh
        if mesh is not None:
            n = mesh_size(mesh)
            if self.max_batch % n:
                raise ValueError(
                    f"max_batch {self.max_batch} must divide over the "
                    f"{n}-device wave mesh")
            self.buckets = tuple(n * b
                                 for b in bucket_sizes(self.max_batch // n))
        else:
            self.buckets = bucket_sizes(self.max_batch)
        # explicit None check: a fresh shared cache is empty == falsy
        self.cache = RunnerCache() if runner_cache is None else runner_cache
        self.scheduler = WaveScheduler(self.max_batch, self.policy)
        self.straggler = StragglerMonitor()
        self.executor = WaveExecutor(self.cache, self.policy,
                                     faults=faults,
                                     straggler=self.straggler)
        self.heartbeat = (Heartbeat(heartbeat_dir, host=heartbeat_host)
                          if heartbeat_dir else None)
        self.macs_per_image = graph.macs((1,) + self.hwc)
        # precision ladder: level 0 is the full-precision graph; higher
        # levels are pre-registered cheaper variants (register_degraded)
        self._variants: list[tuple[str, NetworkGraph, int]] = [
            ("full", graph, self.macs_per_image)]
        self.controller = OverloadController(1, self.policy)
        # counters
        self.waves = 0
        self.waves_failed = 0
        self.images_served = 0
        self.requests_served = 0
        self.requests_failed = 0
        self.requests_expired = 0
        self.requests_rejected = 0
        self.requests_shed = 0
        self.wave_seconds: list[float] = []
        self.wave_occupancy: list[float] = []
        self.request_latencies: list[float] = []
        self.images_by_level: dict[str, int] = {}
        self.quarantined: list[ConvRequest] = []
        self.expired: list[ConvRequest] = []
        if verbose:
            print(f"ConvServeEngine: graph {graph.signature()} @ "
                  f"{h}x{w}x{c}, max_batch {self.max_batch}, buckets "
                  f"{self.buckets}, {self.macs_per_image:,} MACs/image")
            print(graph.summary((1,) + self.hwc))

    # -- precision ladder --------------------------------------------------
    def register_degraded(self, graph: NetworkGraph,
                          label: str | None = None) -> int:
        """Append a cheaper-precision variant to the degradation
        ladder (level ``len-1``); registration order is full precision
        first, cheapest last.  The variant must be frozen and must
        produce the same output geometry as the primary graph for this
        engine's HxWxC — degradation changes codes, never shapes.
        Returns the variant's ladder level."""
        assert graph._out is not None, "freeze the variant (output()) first"
        want = self.graph.out_shape((1,) + self.hwc)
        got = graph.out_shape((1,) + self.hwc)
        if want != got:
            raise ValueError(
                f"degraded variant output shape {got} != primary "
                f"{want} at {self.hwc} — a variant may change "
                f"precision, not geometry")
        level = len(self._variants)
        label = label or f"degraded{level}"
        self._variants.append((label, graph,
                               graph.macs((1,) + self.hwc)))
        # fresh controller sized to the new ladder (registration
        # happens at setup time, before traffic)
        self.controller = OverloadController(len(self._variants),
                                             self.policy)
        return level

    @property
    def variants(self) -> tuple[str, ...]:
        return tuple(label for label, _, _ in self._variants)

    # -- admission ---------------------------------------------------------
    def submit(self, req: ConvRequest):
        """Validate then enqueue.  Unservable payloads raise
        :class:`RequestValidationError` and a full queue raises
        :class:`QueueFullError` — in both cases the request never
        enters the queue and can never poison a wave."""
        try:
            n = validate_request_image(req.image, self.hwc,
                                       max_images=self.max_batch)
        except Exception:
            self.requests_rejected += 1
            req.status = "rejected"
            raise
        try:
            self.scheduler.submit(req, n, self.clock())
        except QueueFullError:
            self.requests_shed += 1
            req.status = "shed"
            raise

    def pending_images(self) -> int:
        return self.scheduler.queued_images

    def wave_ready(self) -> bool:
        return self.scheduler.wave_ready(self.clock())

    def next_deadline(self) -> float | None:
        return self.scheduler.next_deadline()

    # -- one admission step ------------------------------------------------
    def step(self, force: bool = False) -> list[ConvRequest]:
        """One pass of the admission loop: expire aged-out requests,
        decide whether a wave should close (deadline-or-full; ``force``
        closes any non-empty queue — the drain path), pick the
        precision level under current pressure, execute, and either
        complete or quarantine the wave.  Returns the requests *served*
        by this step (empty when no wave closed or the wave failed)."""
        now = self.clock()
        for req in self.scheduler.expire(now):
            self.requests_expired += 1
            self.expired.append(req)
        if not self.scheduler.queue:
            return []
        if not (force or self.scheduler.wave_ready(now)):
            return []
        level = self.controller.observe(self.scheduler.pressure())
        label, graph, macs_img = self._variants[level]
        wave = self.scheduler.take()
        filled = sum(request_images(r.image) for r in wave)
        bucket = bucket_for(filled, self.buckets)
        batch, plan = pack_wave([r.image for r in wave], bucket,
                                hwc=self.hwc)
        out_shape = graph.out_shape((bucket,) + self.hwc)
        try:
            out, dt, attempts = self.executor.execute(
                graph, self.hwc, bucket, batch, out_shape,
                mesh=self.mesh)
        except WaveExecutionError as e:
            # Quarantine: only this wave's requests fail; the engine
            # keeps admitting and serving subsequent waves.
            for req in wave:
                req.status = "failed"
                req.error = e
                req.done = False
            self.requests_failed += len(wave)
            self.waves_failed += 1
            self.quarantined.extend(wave)
            if self.heartbeat is not None:
                self.heartbeat.beat(self.waves, step_time_s=None)
            return []
        for req, res in zip(wave, unpack_wave(out, plan)):
            req.out = res
            req.done = True
            req.status = "served"
            req.wave = self.waves
            req.latency_s = dt
            req.precision = label
            req.level = level
            req.degraded = level > 0
            req.attempts = attempts
            # queue wait (engine clock) + execution (wall clock): the
            # end-to-end latency the p50/p99 SLO tracks
            req.e2e_latency_s = (now - req.submitted_at) + dt
            self.request_latencies.append(req.e2e_latency_s)
        self.waves += 1
        self.images_served += plan.filled
        self.requests_served += len(wave)
        self.wave_seconds.append(dt)
        self.wave_occupancy.append(plan.occupancy)
        self.images_by_level[label] = \
            self.images_by_level.get(label, 0) + plan.filled
        if self.heartbeat is not None:
            self.heartbeat.beat(self.waves, step_time_s=dt)
        return wave

    def run_wave(self) -> list[ConvRequest]:
        """Close and execute one wave from whatever is queued (legacy
        immediate-drain entrypoint)."""
        return self.step(force=True)

    def run(self) -> list[ConvRequest]:
        """Drain the queue; returns *served* requests in wave order
        (quarantined/expired requests are in ``self.quarantined`` /
        ``self.expired`` with their typed errors)."""
        finished: list[ConvRequest] = []
        while self.scheduler.queue:
            finished.extend(self.step(force=True))
        return finished

    # -- counters ----------------------------------------------------------
    def stats(self) -> dict:
        total_s = sum(self.wave_seconds)
        lat = np.asarray(self.request_latencies, np.float64)
        hb = None
        if self.heartbeat is not None:
            rec = self.heartbeat.last()
            hb = {"host": self.heartbeat.host,
                  "step": rec["step"] if rec else None,
                  "path": str(self.heartbeat.path)}
        return {
            "waves": self.waves,
            "waves_failed": self.waves_failed,
            "images_served": self.images_served,
            "requests_served": self.requests_served,
            "requests_failed": self.requests_failed,
            "requests_expired": self.requests_expired,
            "requests_rejected": self.requests_rejected,
            "requests_shed": self.requests_shed,
            "queued_images": self.scheduler.queued_images,
            "max_batch": self.max_batch,
            "buckets": list(self.buckets),
            "images_per_s": self.images_served / total_s if total_s else 0.0,
            "macs_per_s": (self.images_served * self.macs_per_image
                           / total_s if total_s else 0.0),
            "mean_wave_s": total_s / self.waves if self.waves else 0.0,
            "mean_occupancy": (sum(self.wave_occupancy)
                               / len(self.wave_occupancy)
                               if self.wave_occupancy else 0.0),
            "p50_latency_ms": (float(np.percentile(lat, 50)) * 1e3
                               if lat.size else None),
            "p99_latency_ms": (float(np.percentile(lat, 99)) * 1e3
                               if lat.size else None),
            "wave_retries": self.executor.retries,
            "wave_exec_failures": self.executor.failures,
            "runner_cache": {"size": len(self.cache),
                             "hits": self.cache.hits,
                             "misses": self.cache.misses,
                             "evictions": self.cache.evictions},
            "degradation": {**self.controller.stats(),
                            "variants": list(self.variants),
                            "images_by_level": dict(self.images_by_level)},
            "stragglers": self.straggler.stragglers(),
            "straggler_fleet": self.straggler.fleet_summary(),
            "heartbeat": hb,
        }
