"""Lane-batched inference engine for HOBFLOPS ``NetworkGraph`` models.

The transformer engine (``serve/engine.py``, DESIGN.md §6) batches
requests into decode *slots* of a lockstep wave; the CNN engine here
exploits the HOBFLOPS-specific fact that the bitslice carrier's
pixel-row axis *is* the batch axis (DESIGN.md §10): N queued images
coalesce into one ``[N,H,W,C]`` wave that runs through the resident
graph as one compiled call — one activation encode, one decode, and
every plane netlist sweeping all N requests' rows at once.  Serving
cost per image falls with occupancy because the per-wave fixed costs
(dispatch, pack/unpack, netlist op issue) are batch-invariant until
the arrays saturate the machine.

Scheduling is wave admission: up to ``max_batch`` images of queued
requests (whole requests only) are admitted per wave, the wave size is
rounded up to a power-of-two batch *bucket* (compiled shapes stay
bounded; the ragged tail rides as zero-image pad), and results are
sliced back per request bit-exactly (``lanes.py``).  ``max_batch``
defaults to a row budget derived from the kernel's tuned row blocking:
the largest power of two keeping ``B*H*W`` within ``p_block * 512``
rows.  An optional ``wave`` device mesh shards each wave's batch axis
over devices (``sharding.py``); buckets then scale to mesh-size
multiples.

Throughput/latency/occupancy counters aggregate per wave and surface
through :meth:`ConvServeEngine.stats`; ``benchmarks/serve.py`` turns
them into the ``BENCH_serve.json`` trajectory.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque

import jax
import numpy as np

from repro.kernels.conv2d_bitslice.network import NetworkGraph
from repro.kernels.conv2d_bitslice.ops import derive_blocks
from repro.serve_conv.cache import RunnerCache, bucket_for, bucket_sizes
from repro.serve_conv.lanes import pack_wave, request_images, unpack_wave
from repro.serve_conv.sharding import mesh_size, wave_sharded_runner


@dataclasses.dataclass
class ConvRequest:
    """One queued inference request: a single [H,W,C] image or a
    [B,H,W,C] mini-batch (heterogeneous counts mix freely in a
    wave)."""
    rid: int
    image: np.ndarray
    out: np.ndarray | None = None
    done: bool = False
    wave: int | None = None          # which wave served it
    latency_s: float | None = None   # wave execution time it rode in


def derive_max_batch(hwc, p_block: int = 8, row_budget_blocks: int = 512,
                     cap: int = 64) -> int:
    """Wave admission budget from the tuned row blocking: the largest
    power of two whose wave stays within ``p_block * row_budget_blocks``
    carrier rows (B*H*W), clamped to [1, cap]."""
    h, w, _ = hwc
    budget = max(1, (p_block * row_budget_blocks) // (h * w))
    b = 1
    while b * 2 <= min(budget, cap):
        b *= 2
    return b


class ConvServeEngine:
    """Wave-scheduled lane-batched serving of one frozen
    :class:`NetworkGraph` at one input geometry.

    >>> eng = ConvServeEngine(graph, (H, W, C))
    >>> eng.submit(ConvRequest(0, img))
    >>> done = eng.run()
    >>> eng.stats()["images_per_s"], eng.stats()["mean_occupancy"]

    Every request's output is bit-identical to ``graph.run`` on that
    request alone — packing, bucket pad, and sharding never change a
    single code (tests assert it).
    """

    def __init__(self, graph: NetworkGraph, hwc, *,
                 max_batch: int | None = None, blocks: dict | None = None,
                 mesh=None, runner_cache: RunnerCache | None = None,
                 verbose: bool = False):
        assert graph._out is not None, "freeze the graph (output()) first"
        self.graph = graph
        self.hwc = tuple(hwc)
        h, w, c = self.hwc
        # tuned block dicts carry only the swept keys (missing ones mean
        # "use the derived default", same as the kernel launch)
        p_block = (blocks or {}).get("p_block") \
            or derive_blocks(h * w, 1, 1)["p_block"]
        self.max_batch = max_batch or derive_max_batch(self.hwc, p_block)
        self.mesh = mesh
        if mesh is not None:
            n = mesh_size(mesh)
            if self.max_batch % n:
                raise ValueError(
                    f"max_batch {self.max_batch} must divide over the "
                    f"{n}-device wave mesh")
            self.buckets = tuple(n * b
                                 for b in bucket_sizes(self.max_batch // n))
        else:
            self.buckets = bucket_sizes(self.max_batch)
        # explicit None check: a fresh shared cache is empty == falsy
        self.cache = RunnerCache() if runner_cache is None else runner_cache
        self.queue: deque[ConvRequest] = deque()
        self.macs_per_image = graph.macs((1,) + self.hwc)
        # counters
        self.waves = 0
        self.images_served = 0
        self.requests_served = 0
        self.wave_seconds: list[float] = []
        self.wave_occupancy: list[float] = []
        if verbose:
            print(f"ConvServeEngine: graph {graph.signature()} @ "
                  f"{h}x{w}x{c}, max_batch {self.max_batch}, buckets "
                  f"{self.buckets}, {self.macs_per_image:,} MACs/image")
            print(graph.summary((1,) + self.hwc))

    # -- admission ---------------------------------------------------------
    def submit(self, req: ConvRequest):
        n = request_images(req.image)
        if n > self.max_batch:
            raise ValueError(
                f"request {req.rid} carries {n} images > max_batch "
                f"{self.max_batch}; split it across requests")
        if np.shape(req.image)[-3:] != self.hwc:
            raise ValueError(
                f"request {req.rid} geometry "
                f"{np.shape(req.image)[-3:]} != engine geometry "
                f"{self.hwc}")
        self.queue.append(req)

    def _admit(self) -> list[ConvRequest]:
        """Pop whole requests while the wave stays within max_batch."""
        wave, filled = [], 0
        while self.queue:
            n = request_images(self.queue[0].image)
            if wave and filled + n > self.max_batch:
                break
            wave.append(self.queue.popleft())
            filled += n
        return wave

    def _runner(self, bucket: int):
        if self.mesh is None:
            return self.cache.get(self.graph, self.hwc, bucket)
        return self.cache.get(
            self.graph, self.hwc, bucket,
            build=lambda: wave_sharded_runner(self.graph, self.mesh),
            variant=f"wave{mesh_size(self.mesh)}")

    # -- one wave ----------------------------------------------------------
    def run_wave(self) -> list[ConvRequest]:
        wave = self._admit()
        if not wave:
            return []
        batch, plan = pack_wave([r.image for r in wave],
                                bucket_for(
                                    sum(request_images(r.image)
                                        for r in wave), self.buckets),
                                hwc=self.hwc)
        runner = self._runner(plan.bucket)
        t0 = time.perf_counter()
        out = np.asarray(jax.block_until_ready(runner(batch)))
        dt = time.perf_counter() - t0
        for req, res in zip(wave, unpack_wave(out, plan)):
            req.out = res
            req.done = True
            req.wave = self.waves
            req.latency_s = dt
        self.waves += 1
        self.images_served += plan.filled
        self.requests_served += len(wave)
        self.wave_seconds.append(dt)
        self.wave_occupancy.append(plan.occupancy)
        return wave

    def run(self) -> list[ConvRequest]:
        """Drain the queue; returns served requests in wave order."""
        finished: list[ConvRequest] = []
        while self.queue:
            finished.extend(self.run_wave())
        return finished

    # -- counters ----------------------------------------------------------
    def stats(self) -> dict:
        total_s = sum(self.wave_seconds)
        return {
            "waves": self.waves,
            "images_served": self.images_served,
            "requests_served": self.requests_served,
            "max_batch": self.max_batch,
            "buckets": list(self.buckets),
            "images_per_s": self.images_served / total_s if total_s else 0.0,
            "macs_per_s": (self.images_served * self.macs_per_image
                           / total_s if total_s else 0.0),
            "mean_wave_s": total_s / self.waves if self.waves else 0.0,
            "mean_occupancy": (sum(self.wave_occupancy)
                               / len(self.wave_occupancy)
                               if self.wave_occupancy else 0.0),
            "runner_cache": {"size": len(self.cache),
                             "hits": self.cache.hits,
                             "misses": self.cache.misses},
        }
