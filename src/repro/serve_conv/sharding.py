"""Multi-device wave sharding for the lane-batched serve engine.

A packed wave is ``[B, H, W, C]`` images that become ``B*H*W`` pixel
rows of the plane carrier — requests occupy disjoint row slabs (lanes
carry channels; see ``lanes.py``).  Sharding a wave therefore splits
the batch axis over a 1-D ``wave`` mesh: each device encodes, runs,
and decodes its own slab of whole images through the same compiled
resident graph.  No cross-device communication exists anywhere in the
graph body (every plane op is row-local to an image), so the only
collective is the implicit gather of ``out_specs``.

Bit-exactness is inherited from the lane-packing argument: an image's
rows compute identical codes whether its slab is the whole wave or a
per-device shard, and each shard still performs exactly one encode and
one decode.  ``tests/test_serve_conv.py`` asserts the sharded wave
output equals the single-device wave bit-for-bit on a CPU mesh (and on
a forced 2-device host in a subprocess).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.kernels.conv2d_bitslice.network import NetworkGraph
from repro.launch.mesh import _mk
from repro.serve_conv.errors import WaveShardingError


def _shard_map():
    sm = getattr(jax, "shard_map", None)
    if sm is None:  # older jax keeps it in experimental
        from jax.experimental.shard_map import shard_map as sm
    return sm


def wave_mesh(ndev: int | None = None):
    """A 1-D ``wave`` mesh over the first ``ndev`` local devices (all
    of them by default)."""
    n = ndev or len(jax.devices())
    return _mk((n,), ("wave",))


def mesh_size(mesh) -> int:
    return int(mesh.devices.size)


def wave_sharded_runner(graph: NetworkGraph, mesh=None):
    """A wave entrypoint ``images [B,H,W,C] -> [B,Ho,Wo,M]`` that
    shard_maps the graph's compiled resident runner over the batch
    axis.  ``B`` must divide by the mesh size (the engine guarantees
    this by scaling its batch buckets to multiples of it); weights are
    replicated."""
    mesh = mesh or wave_mesh()
    n = mesh_size(mesh)
    fn, weights = graph._resident_fn, graph._live_weights
    sharded = _shard_map()(fn, mesh=mesh, in_specs=(P("wave"), P()),
                           out_specs=P("wave"))

    def runner(images):
        images = jnp.asarray(images, jnp.float32)
        if images.shape[0] % n:
            raise WaveShardingError(
                f"wave batch {images.shape[0]} does not divide over "
                f"the {n}-device wave mesh")
        return sharded(images, weights)

    return runner
