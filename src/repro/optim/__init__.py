from .adamw import OptConfig, adamw_init, adamw_update, global_norm, lr_at

__all__ = ["OptConfig", "adamw_init", "adamw_update", "global_norm",
           "lr_at"]
