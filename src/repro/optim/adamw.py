"""AdamW with global-norm clipping and warmup-cosine schedule.

Moments live in ``moment_dtype``; at 300B+ parameters on a 256-chip pod
the f32 (m, v) pair alone exceeds HBM, so the giant configs run bf16
moments (the classic memory/precision trade — recorded per-arch in the
dry-run table).  Moments are sharded exactly like their parameters
(which the schema rules already shard over BOTH the data/FSDP and model
axes), so this is ZeRO-3-flavored state partitioning for free.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    moment_dtype: str = "float32"


def lr_at(opt: OptConfig, step):
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(1.0, (step + 1.0) / max(opt.warmup_steps, 1))
    prog = jnp.clip((step - opt.warmup_steps)
                    / max(opt.total_steps - opt.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    frac = opt.min_lr_ratio + (1.0 - opt.min_lr_ratio) * cos
    return opt.lr * warm * frac


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_init(params, opt: OptConfig):
    dt = jnp.dtype(opt.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params)}


def adamw_update(params, grads, opt_state, step, opt: OptConfig):
    """Returns (new_params, new_opt_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, opt.clip / jnp.maximum(gnorm, 1e-9))
    lr = lr_at(opt, step)
    t = jnp.asarray(step, jnp.float32) + 1.0
    bc1 = 1.0 - opt.beta1 ** t
    bc2 = 1.0 - opt.beta2 ** t
    mdt = jnp.dtype(opt.moment_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = opt.beta1 * m.astype(jnp.float32) + (1 - opt.beta1) * g
        v32 = opt.beta2 * v.astype(jnp.float32) + (1 - opt.beta2) * g * g
        mhat = m32 / bc1
        vhat = v32 / bc2
        step_ = mhat / (jnp.sqrt(vhat) + opt.eps)
        p32 = p.astype(jnp.float32)
        p_new = p32 - lr * (step_ + opt.weight_decay * p32)
        return p_new.astype(p.dtype), m32.astype(mdt), v32.astype(mdt)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    out = [upd(p, g, m, v)
           for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v}, {"grad_norm": gnorm, "lr": lr}
