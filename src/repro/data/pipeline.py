"""Deterministic synthetic data pipeline.

Batches are a pure function of (seed, step) — no files, no state.  That
is exactly what restart-from-checkpoint needs: a restored step counter
reproduces the identical data stream on any number of hosts, and each
host materializes only its addressable shard (``place_batch``), so the
pipeline is elastic by construction.

The token stream is a order-3 LCG-mixed sequence: cheap, seeded, with
enough structure that cross-entropy decreases visibly during the
example training runs (unlike iid-uniform tokens, which are unlearnable
beyond the unigram floor).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # model-input extras (modality stubs)
    num_prefix: int = 0
    frontend_dim: int = 0
    frames: bool = False

    def batch_at(self, step: int) -> dict:
        return make_batch(self, step)


def _token_stream(ds: SyntheticLM, step: int) -> np.ndarray:
    """[B, S+1] int32.  Learnable structure: next token is a mix of an
    LCG of the previous token and a slowly-varying per-row offset."""
    B, S, V = ds.global_batch, ds.seq_len, ds.vocab
    rng = np.random.default_rng((ds.seed, step))
    x = np.empty((B, S + 1), dtype=np.int64)
    x[:, 0] = rng.integers(0, V, size=B)
    row = rng.integers(0, V, size=(B, 1))
    noise = rng.integers(0, V, size=(B, S))
    noisy = rng.random((B, S)) < 0.1
    for t in range(S):
        nxt = (x[:, t] * 1103515245 + 12345 + row[:, 0]) % V
        x[:, t + 1] = np.where(noisy[:, t], noise[:, t], nxt)
    return x.astype(np.int32)


def make_batch(ds: SyntheticLM, step: int) -> dict:
    toks = _token_stream(ds, step)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    rng = np.random.default_rng((ds.seed, step, 1))
    if ds.num_prefix:
        batch["prefix"] = rng.standard_normal(
            (ds.global_batch, ds.num_prefix, ds.frontend_dim),
            dtype=np.float32)
    if ds.frames:
        batch["frames"] = rng.standard_normal(
            (ds.global_batch, ds.seq_len, ds.frontend_dim), dtype=np.float32)
    return batch


def place_batch(batch: dict, shardings: dict):
    """Host batch -> sharded device arrays.  Only the addressable shard
    of each array is copied to devices (multi-host ready)."""
    out = {}
    for name, arr in batch.items():
        sh = shardings[name]
        out[name] = jax.make_array_from_callback(
            arr.shape, sh, lambda idx, a=arr: a[idx])
    return out


def dataset_for(cfg, shape, seed: int = 0) -> SyntheticLM:
    """Dataset matching a (ModelConfig, ShapeConfig) cell."""
    return SyntheticLM(
        vocab=cfg.vocab, seq_len=shape.seq_len,
        global_batch=shape.global_batch, seed=seed,
        num_prefix=cfg.num_prefix if cfg.family != "encdec" else 0,
        frontend_dim=cfg.frontend_dim,
        frames=cfg.family == "encdec")
