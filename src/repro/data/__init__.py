from .pipeline import SyntheticLM, make_batch, place_batch

__all__ = ["SyntheticLM", "make_batch", "place_batch"]
