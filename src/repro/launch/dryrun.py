import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above run before any other import — jax locks the device
count at first init, and the production meshes need 512 placeholder
devices on this CPU-only container.  Everything else (tests, benches,
examples) sees the real single device.

Per cell this produces (written to experiments/dryrun/<cell>.json):
  * compile proof: .lower().compile() succeeded under the target mesh
  * memory_analysis()  — per-device argument/output/temp bytes
  * cost_analysis()    — XLA's aggregate (loop bodies counted once)
  * loop-aware per-chip flops / bytes / collective-bytes from
    repro.launch.hlo_cost (trip-count corrected) — §Roofline inputs
"""
import argparse
import dataclasses
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import (ARCH_NAMES, batch_specs, decode_specs,
                           get_config)
from repro.distributed.ctx import act_rules
from repro.distributed.sharding import (batch_pspecs, cache_pspecs, named,
                                        state_pspecs)
from repro.launch import hlo_cost
from repro.launch.mesh import make_production_mesh
from repro.models import model_schema
from repro.models.config import SHAPES, shape_applicable
from repro.models.schema import (abstract_params, logical_spec, make_rules,
                                 param_count, pspecs)
from repro.optim import OptConfig
from repro.serve.steps import make_decode_step, make_prefill_step
from repro.train.step import TrainConfig, abstract_state, make_train_step

# Per-arch runtime knobs for the production cells.  n_micro keeps the
# activation working set inside HBM; sequence parallelism is on by
# default (see distributed.ctx); bf16 moments/grads are the only way
# 300B+ parameter Adam states fit a 256-chip pod at all.
RUNTIME: dict[str, dict] = {
    "grok-1-314b": dict(n_micro=8, moment_dtype="bfloat16",
                        grad_dtype="bfloat16"),
    "llama3-405b": dict(n_micro=8, moment_dtype="bfloat16",
                        grad_dtype="bfloat16"),
    "internvl2-26b": dict(n_micro=8),
    "jamba-v0.1-52b": dict(n_micro=8),
    "olmoe-1b-7b": dict(n_micro=4),
    "gemma-2b": dict(n_micro=4),
    "qwen3-4b": dict(n_micro=4),
    "qwen2-0.5b": dict(n_micro=2),
    "mamba2-2.7b": dict(n_micro=8),
    "seamless-m4t-medium": dict(n_micro=2),
}

PEAK_FLOPS = 197e12      # bf16 per chip (TPU v5e)
HBM_BW = 819e9           # bytes/s per chip
LINK_BW = 50e9           # bytes/s per ICI link


def build_lowered(arch: str, shape_name: str, mesh_kind: str,
                  overrides: dict | None = None):
    """Returns (lowered, info) for one cell."""
    overrides = overrides or {}
    cfg = get_config(arch)
    if overrides.get("cfg_replace"):
        cfg = dataclasses.replace(cfg, **overrides["cfg_replace"])
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rt = dict(RUNTIME.get(arch, {}))
    rt.update(overrides)
    rules = make_rules(mesh,
                       seq_parallel=rt.get("seq_parallel", True))
    schema = model_schema(cfg)
    info = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
            "kind": shape.kind, "params": param_count(schema),
            "n_devices": mesh.size, "runtime": {
                k: v for k, v in rt.items() if not callable(v)}}

    with mesh, act_rules(rules):
        if shape.kind == "train":
            n_micro = int(rt.get("n_micro", 1))
            while shape.global_batch % n_micro:
                n_micro //= 2
            tc = TrainConfig(
                opt=OptConfig(
                    moment_dtype=rt.get("moment_dtype", "float32")),
                n_micro=n_micro,
                grad_dtype=rt.get("grad_dtype", "float32"))
            info["runtime"]["n_micro"] = n_micro
            step = make_train_step(cfg, tc)
            state = abstract_state(cfg, tc)
            batch = batch_specs(cfg, shape, train=True)
            sspec = state_pspecs(schema, rules)
            bspec = batch_pspecs(batch, rules)
            jfn = jax.jit(step,
                          in_shardings=(named(mesh, sspec),
                                        named(mesh, bspec)),
                          donate_argnums=(0,))
            lowered = jfn.lower(state, batch)
        elif shape.kind == "prefill":
            pf = make_prefill_step(cfg, max_len=shape.seq_len
                                   + cfg.num_prefix)
            params = abstract_params(schema, dtype=jnp.bfloat16)
            batch = batch_specs(cfg, shape, train=False)
            pspec = pspecs(schema, rules)
            bspec = batch_pspecs(batch, rules)
            jfn = jax.jit(pf, in_shardings=(named(mesh, pspec),
                                            named(mesh, bspec)))
            lowered = jfn.lower(params, batch)
        else:  # decode
            params = abstract_params(schema, dtype=jnp.bfloat16)
            pspec = pspecs(schema, rules)
            deq = None
            if rt.get("quant"):
                # HOBFLOPS bitplane weights: the paper's technique as
                # the decode memory-bandwidth lever.
                from repro.quant.apply import (abstract_quantize_params,
                                               make_deq,
                                               quantized_pspecs)
                params = abstract_quantize_params(params, cfg,
                                                  rt["quant"])
                pspec = quantized_pspecs(pspec, params)
                deq = make_deq()
            serve = make_decode_step(cfg, deq=deq)
            specs = decode_specs(cfg, shape)
            tok_spec = logical_spec(rules, "batch",
                                    dims=(shape.global_batch,))
            cspec = cache_pspecs(specs["cache"], rules)
            jfn = jax.jit(
                serve,
                in_shardings=(named(mesh, pspec),
                              named(mesh, tok_spec),
                              named(mesh, jax.sharding.PartitionSpec()),
                              named(mesh, cspec)),
                donate_argnums=(3,))
            lowered = jfn.lower(params, specs["token"], specs["pos"],
                                specs["cache"])
    return lowered, info


def roofline_terms(cost: dict, mesh_kind: str) -> dict:
    """Seconds per step, per chip, for the three roofline terms."""
    t_c = cost["flops"] / PEAK_FLOPS
    t_m = cost["bytes"] / HBM_BW
    # 2D torus, 4 links usable per chip for in-pod collectives.
    t_l = cost["coll_bytes"] / (4 * LINK_BW)
    dom = max((t_c, "compute"), (t_m, "memory"), (t_l, "collective"))
    return {"compute_s": t_c, "memory_s": t_m, "collective_s": t_l,
            "dominant": dom[1],
            "step_s_max": max(t_c, t_m, t_l),
            "step_s_sum": t_c + t_m + t_l}


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: str,
             overrides: dict | None = None, tag: str = "") -> dict:
    out = pathlib.Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    fname = out / f"{arch}__{shape_name}__{mesh_kind}{tag}.json"
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    okay, reason = shape_applicable(cfg, shape)
    if not okay:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
               "status": "skip", "reason": reason}
        fname.write_text(json.dumps(rec, indent=1))
        print(f"SKIP  {arch} {shape_name} {mesh_kind}: {reason}",
              flush=True)
        return rec

    t0 = time.time()
    try:
        lowered, info = build_lowered(arch, shape_name, mesh_kind,
                                      overrides)
        t_lower = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t1
        mem = compiled.memory_analysis()
        mem_rec = {}
        for field in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "alias_size_in_bytes",
                      "generated_code_size_in_bytes"):
            v = getattr(mem, field, None)
            if v is not None:
                mem_rec[field] = int(v)
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0] if ca else {}
        xla_cost = {k: float(v) for k, v in dict(ca or {}).items()
                    if isinstance(v, (int, float)) and k in
                    ("flops", "bytes accessed", "transcendentals",
                     "optimal_seconds")}
        cost = hlo_cost.analyze_compiled(compiled)
        rec = dict(info)
        rec.update({
            "status": "ok",
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "memory_analysis": mem_rec,
            "xla_cost_analysis_loop_once": xla_cost,
            "hlo_cost": cost,
            "roofline": roofline_terms(cost, mesh_kind),
        })
    except Exception as e:  # record the failure; the matrix keeps going
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
               "status": "error", "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
    fname.write_text(json.dumps(rec, indent=1))
    status = rec["status"]
    extra = ""
    if status == "ok":
        r = rec["roofline"]
        extra = (f"compile={rec['compile_s']}s "
                 f"dom={r['dominant']} step={r['step_s_max']:.4f}s "
                 f"temp={rec['memory_analysis'].get('temp_size_in_bytes', 0)/2**30:.2f}GiB")
    else:
        extra = rec.get("error", "")[:200]
    print(f"{status.upper():5s} {arch} {shape_name} {mesh_kind} {extra}",
          flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    archs = ARCH_NAMES if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) \
        else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                run_cell(arch, shape, mesh_kind, args.out)


if __name__ == "__main__":
    main()
