"""Loop-aware cost analysis over compiled HLO text.

``compiled.cost_analysis()`` visits every ``while`` body exactly once,
so any scanned program (all of ours: layers, microbatches, flash
blocks, SSD chunks) is undercounted by the trip count.  XLA's CPU/TPU
pipelines annotate ``backend_config={"known_trip_count":{"n":...}}`` on
while ops after loop analysis; this module re-walks the HLO text and
multiplies each computation's cost by the enclosing trip counts.

Per top-level instruction we account:

  flops      — 2·M·N·K for dots (batch dims folded into the output
               product), element counts for elementwise/reduce work
  bytes      — operand + output bytes (the post-fusion "bytes accessed"
               model); dynamic-slice/DUS/gather/scatter count the moved
               window, not the resident buffer
  coll_bytes — Σ operand bytes of all-reduce / all-gather /
               reduce-scatter / all-to-all / collective-permute (+
               their async -start forms), i.e. per-chip link traffic

The module is post-SPMD-partitioning, so every figure is *per chip*.
"""
from __future__ import annotations

import dataclasses
import json
import re

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "f8e3m4": 1, "f8e4m3b11fnuz": 1, "f8e5m2fnuz": 1,
    "f8e8m0fnu": 1, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "collective-broadcast",
                "ragged-all-to-all")
_SKIP_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "after-all", "partition-id", "replica-id",
             "opt-barrier", "domain", "add-dependency"}


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_hist: dict | None = None
    unknown_trip_loops: int = 0
    # bytes moved by standalone bf16<->f32 converts: the XLA *CPU*
    # backend legalizes bf16 compute by materializing f32 copies; a TPU
    # lowering computes bf16 natively, so this slice of the memory term
    # is a host-backend artifact (reported separately, never subtracted
    # silently).
    convert_bytes: float = 0.0

    def __post_init__(self):
        if self.coll_hist is None:
            self.coll_hist = {}

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.coll_bytes += other.coll_bytes * mult
        for k, v in other.coll_hist.items():
            self.coll_hist[k] = self.coll_hist.get(k, 0.0) + v * mult
        self.unknown_trip_loops += other.unknown_trip_loops
        self.convert_bytes += other.convert_bytes * mult


def _shape_bytes(text: str) -> float:
    """Total bytes of every dtype[dims] group in `text` (tuples sum)."""
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(text: str) -> float:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n
    return total


@dataclasses.dataclass
class Instr:
    name: str
    shape: str          # output shape text (may be a tuple)
    opcode: str
    operands: list[str]
    attrs: str


_INSTR_RE = re.compile(
    r"^\s*(?:ROOT )?%?([\w.\-]+) = (\([^=]*?\)|\S+) ([\w\-]+)\((.*)$")


def _parse_operands(argstr: str) -> tuple[list[str], str]:
    """Split the top-level args of `op(...)`; returns (operand names,
    trailing attr text)."""
    depth = 0
    args, cur = [], []
    i = 0
    for i, ch in enumerate(argstr):
        if ch in "([{":
            depth += 1
            cur.append(ch)
        elif ch in ")]}":
            if depth == 0:
                args.append("".join(cur))
                break
            depth -= 1
            cur.append(ch)
        elif ch == "," and depth == 0:
            args.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    names = []
    for a in args:
        a = a.strip()
        m = re.search(r"%([\w.\-]+)\s*$", a)
        names.append(m.group(1) if m else a)
    return names, argstr[i + 1:]


def parse_computations(hlo: str) -> dict[str, list[Instr]]:
    comps: dict[str, list[Instr]] = {}
    cur: list[Instr] | None = None
    for line in hlo.splitlines():
        stripped = line.strip()
        m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*{$", stripped)
        if m and not stripped.startswith("ROOT") and "=" not in \
                stripped.split("(")[0]:
            cur = comps.setdefault(m.group(1), [])
            if stripped.startswith("ENTRY") or line.startswith("ENTRY"):
                comps["__entry__"] = cur
            continue
        if stripped == "}":
            cur = None
            continue
        if cur is None:
            continue
        # XLA prints /*index=N*/ comments inside large tuple shapes.
        line = re.sub(r"/\*.*?\*/", "", line)
        mi = _INSTR_RE.match(line)
        if mi is None:
            continue
        name, shape, opcode, rest = mi.groups()
        operands, attrs = _parse_operands(rest)
        cur.append(Instr(name, shape, opcode, operands, attrs))
    return comps


def _trip_count(attrs: str) -> int | None:
    m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', attrs)
    return int(m.group(1)) if m else None


def _dot_flops(instr: Instr, shapes: dict[str, str]) -> float:
    out_elems = _shape_elems(instr.shape)
    lhs = shapes.get(instr.operands[0], "")
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.attrs)
    contract = 1
    sm = _SHAPE_RE.search(lhs)
    if m and sm:
        dims = [int(d) for d in sm.group(2).split(",") if d]
        for idx in (int(i) for i in m.group(1).split(",") if i):
            if idx < len(dims):
                contract *= dims[idx]
    return 2.0 * out_elems * contract


def _conv_flops(instr: Instr, shapes: dict[str, str]) -> float:
    out_elems = _shape_elems(instr.shape)
    rhs = shapes.get(instr.operands[1], "") if len(instr.operands) > 1 else ""
    sm = _SHAPE_RE.search(rhs)
    kernel = 1
    if sm:
        for d in sm.group(2).split(","):
            if d:
                kernel *= int(d)
        out_sm = _SHAPE_RE.search(instr.shape)
        if out_sm:
            o = [int(d) for d in out_sm.group(2).split(",") if d]
            kernel //= max(o[-1] if o else 1, 1) or 1
    return 2.0 * out_elems * max(kernel, 1)


def _fusion_bytes(called: list, fusion_instr, outer_shapes,
                  out_bytes: float) -> float:
    """Alias-aware traffic of one fusion instruction.

    Scan programs are made of fusions whose parameters are only *sliced*
    (xs reads: dynamic-slice of the stacked buffer) or *aliased through
    a dynamic-update-slice root* (ys writes / donated in-place updates).
    Counting full parameter buffers there overstates HBM traffic by the
    trip count; instead:

      param used only by dynamic-slice/slice -> 2 x slice bytes
      param aliased into the root DUS       -> 2 x update bytes
      anything else                          -> full parameter bytes
    Output: counted unless the root DUS aliases a parameter (in-place).
    """
    if not called:
        return out_bytes
    inner_shapes = {i.name: i.shape for i in called}
    uses: dict[str, list] = {}
    for i in called:
        for o in i.operands:
            uses.setdefault(o, []).append(i)
    root = called[-1]

    # which inner value feeds the root DUS target (operand 0), following
    # bitcast/copy chains
    aliased_params: set[str] = set()
    root_is_dus = root.opcode == "dynamic-update-slice"
    dus_update_bytes = 0.0
    if root_is_dus:
        dus_update_bytes = _shape_bytes(
            inner_shapes.get(root.operands[1], "")) if len(
                root.operands) > 1 else 0.0
        tgt = root.operands[0] if root.operands else None
        seen = set()
        while tgt and tgt not in seen:
            seen.add(tgt)
            instr = next((i for i in called if i.name == tgt), None)
            if instr is None:
                break
            if instr.opcode == "parameter":
                aliased_params.add(instr.name)
                break
            if instr.opcode in ("bitcast", "copy", "convert") \
                    and instr.operands:
                tgt = instr.operands[0]
            else:
                break

    total = 0.0
    for pname in (i.name for i in called if i.opcode == "parameter"):
        if pname in aliased_params:
            total += 2.0 * dus_update_bytes
            continue
        puses = uses.get(pname, [])
        if puses and all(u.opcode in ("dynamic-slice", "slice")
                         for u in puses):
            total += sum(2.0 * _shape_bytes(inner_shapes.get(u.name, ""))
                         for u in puses)
        else:
            total += _shape_bytes(inner_shapes.get(pname, ""))
    if root_is_dus and aliased_params:
        pass          # in-place: write already counted with the update
    else:
        total += out_bytes
    return total


def analyze(hlo: str) -> Cost:
    comps = parse_computations(hlo)
    shapes_of: dict[str, dict[str, str]] = {
        cname: {i.name: i.shape for i in instrs}
        for cname, instrs in comps.items()}
    memo: dict[str, Cost] = {}
    in_progress: set[str] = set()

    def comp_cost(cname: str) -> Cost:
        if cname in memo:
            return memo[cname]
        if cname in in_progress or cname not in comps:
            return Cost()
        in_progress.add(cname)
        total = Cost()
        shapes = shapes_of[cname]
        for instr in comps[cname]:
            total.add(instr_cost(instr, shapes))
        in_progress.discard(cname)
        memo[cname] = total
        return total

    def instr_cost(instr: Instr, shapes: dict[str, str]) -> Cost:
        c = Cost()
        op = instr.opcode
        if op in _SKIP_OPS:
            return c
        out_bytes = _shape_bytes(instr.shape)
        opd_bytes = sum(_shape_bytes(shapes.get(o, "")) for o in
                        instr.operands)

        if op == "while":
            body = re.search(r"body=%?([\w.\-]+)", instr.attrs)
            cond = re.search(r"condition=%?([\w.\-]+)", instr.attrs)
            trip = _trip_count(instr.attrs)
            inner = Cost()
            if body:
                inner.add(comp_cost(body.group(1)))
            if cond:
                inner.add(comp_cost(cond.group(1)))
            if trip is None:
                trip = 1
                c.unknown_trip_loops += 1
            c.add(inner, float(trip))
            return c
        if op == "conditional":
            branches = re.findall(r"(?:branch_computations=\{([^}]*)\}|"
                                  r"true_computation=%?([\w.\-]+)|"
                                  r"false_computation=%?([\w.\-]+))",
                                  instr.attrs)
            names: list[str] = []
            for tup in branches:
                for t in tup:
                    if t:
                        names.extend(n.strip().lstrip("%")
                                     for n in t.split(","))
            if names:
                worst = max((comp_cost(n) for n in names),
                            key=lambda cc: cc.flops + cc.bytes)
                c.add(worst)
            c.bytes += out_bytes
            return c
        if op == "call":
            # Inlined-by-name computation (remat/jvp "closed_call"):
            # its body ops are real top-level work — take the full cost,
            # and none at the (virtual) call boundary.
            m = re.search(r"to_apply=%?([\w.\-]+)", instr.attrs)
            if m:
                c.add(comp_cost(m.group(1)))
            return c
        if op in ("fusion", "async-start"):
            m = re.search(r"calls=%?([\w.\-]+)", instr.attrs)
            if m:
                inner = comp_cost(m.group(1))
                c.flops += inner.flops
                c.coll_bytes += inner.coll_bytes
                for k, v in inner.coll_hist.items():
                    c.coll_hist[k] = c.coll_hist.get(k, 0.0) + v
                c.unknown_trip_loops += inner.unknown_trip_loops
                c.bytes += _fusion_bytes(comps.get(m.group(1), []),
                                         instr, shapes, out_bytes)
            else:
                c.bytes += opd_bytes + out_bytes
            return c

        base = op.removesuffix("-start")
        if base in _COLLECTIVES:
            moved = opd_bytes
            c.coll_bytes += moved
            c.coll_hist[base] = c.coll_hist.get(base, 0.0) + moved
            c.bytes += opd_bytes + out_bytes
            return c
        if op in ("all-reduce-done", "all-gather-done",
                  "collective-permute-done", "async-done", "async-update",
                  "copy-start", "copy-done", "send", "recv", "send-done",
                  "recv-done"):
            return c

        if op == "dot":
            c.flops += _dot_flops(instr, shapes)
            c.bytes += opd_bytes + out_bytes
            return c
        if op == "convolution":
            c.flops += _conv_flops(instr, shapes)
            c.bytes += opd_bytes + out_bytes
            return c
        if op in ("dynamic-slice", "gather"):
            c.bytes += 2.0 * out_bytes
            return c
        if op == "dynamic-update-slice":
            upd = (_shape_bytes(shapes.get(instr.operands[1], ""))
                   if len(instr.operands) > 1 else out_bytes)
            c.bytes += 2.0 * upd
            return c
        if op == "scatter":
            upd = (_shape_bytes(shapes.get(instr.operands[-1], ""))
                   if instr.operands else out_bytes)
            c.bytes += 3.0 * upd + out_bytes
            c.flops += _shape_elems(instr.shape)
            return c
        if op in ("reduce", "reduce-window"):
            c.flops += sum(_shape_elems(shapes.get(o, ""))
                           for o in instr.operands)
            c.bytes += opd_bytes + out_bytes
            return c
        if op == "sort":
            n = _shape_elems(instr.shape)
            c.flops += n * max(n, 2).bit_length()
            c.bytes += opd_bytes + out_bytes
            return c

        # generic elementwise / data movement
        if op == "convert":
            in_t = shapes.get(instr.operands[0], "") if instr.operands \
                else ""
            pair = {m.group(1) for m in
                    ( _SHAPE_RE.search(t) for t in (in_t, instr.shape))
                    if m}
            if pair == {"bf16", "f32"}:
                c.convert_bytes += opd_bytes + out_bytes
        c.flops += _shape_elems(instr.shape)
        c.bytes += opd_bytes + out_bytes
        return c

    entry = "__entry__" if "__entry__" in comps else next(iter(comps))
    return comp_cost(entry)


def analyze_compiled(compiled) -> dict:
    """Cost dict (per chip) for a jax compiled object."""
    cost = analyze(compiled.as_text())
    return {
        "flops": cost.flops,
        "bytes": cost.bytes,
        "coll_bytes": cost.coll_bytes,
        "coll_hist": cost.coll_hist,
        "unknown_trip_loops": cost.unknown_trip_loops,
        "cpu_bf16_convert_bytes": cost.convert_bytes,
    }


def top_contributors(hlo: str, n: int = 20):
    """Top-n instructions by bytes x enclosing-loop trips (debugging /
    hillclimbing aid).  Returns [(bytes_total, trips, opcode, name,
    shape<=120ch)]."""
    comps = parse_computations(hlo)
    shapes_of = {c: {i.name: i.shape for i in instrs}
                 for c, instrs in comps.items()}

    # map computation -> multiplier (product of trips of enclosing whiles)
    mult: dict[str, float] = {}

    def mark(cname: str, m: float):
        if cname not in comps:
            return
        mult[cname] = mult.get(cname, 0.0) + m
        for instr in comps[cname]:
            if instr.opcode == "while":
                body = re.search(r"body=%?([\w.\-]+)", instr.attrs)
                cond = re.search(r"condition=%?([\w.\-]+)", instr.attrs)
                trip = _trip_count(instr.attrs) or 1
                for mm in (body, cond):
                    if mm:
                        mark(mm.group(1), m * trip)
            else:
                for attr in ("calls", "to_apply"):
                    mm = re.search(attr + r"=%?([\w.\-]+)", instr.attrs)
                    if mm and instr.opcode in ("fusion", "call",
                                               "async-start"):
                        mark(mm.group(1), m)

    entry = "__entry__" if "__entry__" in comps else next(iter(comps))
    mark(entry, 1.0)

    rows = []
    for cname, instrs in comps.items():
        m = mult.get(cname, 0.0)
        if m <= 0:
            continue
        shapes = shapes_of[cname]
        for i in instrs:
            if i.opcode in _SKIP_OPS or i.opcode in ("while",):
                continue
            b = (_shape_bytes(i.shape)
                 + sum(_shape_bytes(shapes.get(o, ""))
                       for o in i.operands))
            if i.opcode in ("dynamic-slice", "gather"):
                b = 2 * _shape_bytes(i.shape)
            rows.append((b * m, m, i.opcode, f"{cname}/{i.name}",
                         i.shape[:120]))
    rows.sort(reverse=True)
    return rows[:n]
