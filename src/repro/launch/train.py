"""Training launcher: data -> step -> metrics -> checkpoint -> heartbeat.

Runs anywhere: full configs on a production mesh, or ``--smoke`` on
this container's CPU device.  Restart-safe by construction — on start
it restores the newest complete checkpoint (if any) and the synthetic
data pipeline replays from the restored step.  ``--kill-at`` simulates
a mid-run crash for the fault-tolerance tests/examples.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, smoke_config
from repro.data.pipeline import SyntheticLM, make_batch
from repro.distributed.sharding import batch_pspecs, named, state_pspecs
from repro.ft import Heartbeat, StragglerMonitor
from repro.launch.mesh import host_mesh
from repro.models import model_schema
from repro.models.config import ShapeConfig
from repro.models.schema import make_rules
from repro.optim import OptConfig
from repro.train.step import TrainConfig, init_state, make_train_step


def train_loop(cfg, shape, *, steps: int, tc: TrainConfig | None = None,
               ckpt_dir: str | None = None, ckpt_every: int = 50,
               hb_dir: str | None = None, host: str = "host0",
               mesh=None, seed: int = 0, kill_at: int | None = None,
               log_every: int = 10, print_fn=print):
    """Returns (final_state, losses)."""
    tc = tc or TrainConfig(opt=OptConfig(warmup_steps=20,
                                         total_steps=steps))
    mesh = mesh or host_mesh()
    rules = make_rules(mesh)
    schema = model_schema(cfg)
    sspecs = named(mesh, state_pspecs(schema, rules))

    ds = SyntheticLM(
        vocab=cfg.vocab, seq_len=shape.seq_len,
        global_batch=shape.global_batch, seed=seed,
        num_prefix=cfg.num_prefix if cfg.family != "encdec" else 0,
        frontend_dim=cfg.frontend_dim, frames=cfg.family == "encdec")

    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    hb = Heartbeat(hb_dir, host) if hb_dir else None
    mon = StragglerMonitor()

    with mesh:
        step_fn = jax.jit(make_train_step(cfg, tc), donate_argnums=(0,))
        start = 0
        state = None
        if mgr is not None:
            import jax.numpy as jnp
            from repro.train.step import abstract_state
            restored_step, restored = mgr.restore_latest(
                abstract_state(cfg, tc), sspecs)
            if restored is not None:
                state, start = restored, restored_step + 1
                print_fn(f"[train] restored checkpoint step "
                         f"{restored_step}; resuming at {start}")
        if state is None:
            state = init_state(cfg, tc, jax.random.PRNGKey(seed))
            state = jax.device_put(state, sspecs)

        losses = []
        for step in range(start, steps):
            t0 = time.time()
            batch = make_batch(ds, step)
            bspecs = named(mesh, batch_pspecs(batch, rules))
            batch = jax.device_put(batch, bspecs)
            state, metrics = step_fn(state, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            dt = time.time() - t0
            mon.observe(host, dt)
            if hb is not None:
                hb.beat(step, dt)
            if step % log_every == 0 or step == steps - 1:
                print_fn(f"[train] step {step:5d} loss {loss:.4f} "
                         f"gnorm {float(metrics['grad_norm']):.3f} "
                         f"lr {float(metrics['lr']):.2e} {dt:.2f}s")
            if mgr is not None and (step + 1) % ckpt_every == 0:
                mgr.save(step, state)
            if kill_at is not None and step >= kill_at:
                print_fn(f"[train] simulated crash at step {step}")
                if mgr is not None:
                    mgr.wait()
                return state, losses
        if mgr is not None:
            mgr.save(steps - 1, state, block=True)
    return state, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--hb-dir", default=None)
    ap.add_argument("--kill-at", type=int, default=None)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    train_loop(cfg, shape, steps=args.steps, ckpt_dir=args.ckpt_dir,
               hb_dir=args.hb_dir, kill_at=args.kill_at)


if __name__ == "__main__":
    main()
