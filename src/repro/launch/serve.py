"""Serving launcher: batched prefill + greedy decode loop.

``--quant hobflops9`` stores the targeted weight families as HOBFLOPS
bitplane codes and dequantizes on the fly — the paper's custom-precision
FP as a serving memory-bandwidth feature.  Runs smoke configs on CPU;
the production meshes use the same step builders via launch.dryrun.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.models import model_schema
from repro.models.schema import init_params
from repro.quant.apply import make_deq, quantize_params
from repro.serve.steps import make_decode_step, make_prefill_step


def serve_demo(cfg, *, batch: int = 2, prompt_len: int = 32,
               gen_len: int = 16, quant: str | None = None,
               seed: int = 0, print_fn=print):
    key = jax.random.PRNGKey(seed)
    params = init_params(model_schema(cfg), key)
    deq = None
    if quant:
        params, deq = quantize_params(params, cfg, quant)
        print_fn(f"[serve] quantized weights to {quant} (bitplane)")

    max_len = prompt_len + gen_len + cfg.num_prefix
    prefill = jax.jit(make_prefill_step(cfg, max_len, deq=deq))
    step = jax.jit(make_decode_step(cfg, deq=deq))

    batch_in = {"tokens": jax.random.randint(key, (batch, prompt_len), 0,
                                             cfg.vocab)}
    if cfg.frontend != "none" and cfg.family != "encdec":
        batch_in["prefix"] = jax.random.normal(
            key, (batch, cfg.num_prefix, cfg.frontend_dim))
    if cfg.family == "encdec":
        batch_in["frames"] = jax.random.normal(
            key, (batch, prompt_len, cfg.frontend_dim))

    t0 = time.time()
    cache, logits, length = prefill(params, batch_in)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    print_fn(f"[serve] prefill {prompt_len} tokens x{batch}: "
             f"{time.time()-t0:.2f}s")

    out_tokens = [np.asarray(tok)]
    t1 = time.time()
    pos = jnp.asarray(length, jnp.int32)
    for i in range(gen_len - 1):
        tok, logits, cache = step(params, tok, pos + i, cache)
        out_tokens.append(np.asarray(tok))
    dt = time.time() - t1
    toks = np.stack(out_tokens, 1)
    print_fn(f"[serve] decoded {gen_len-1} steps x{batch} in {dt:.2f}s "
             f"({batch*(gen_len-1)/max(dt,1e-9):.1f} tok/s)")
    print_fn(f"[serve] sample output ids: {toks[0][:12].tolist()}")
    return toks


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--quant", default=None,
                    help="e.g. hobflops9 — bitplane weight storage")
    args = ap.parse_args()
    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    serve_demo(cfg, batch=args.batch, prompt_len=args.prompt_len,
               gen_len=args.gen_len, quant=args.quant)


if __name__ == "__main__":
    main()
