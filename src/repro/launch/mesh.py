"""Production mesh factories.

Functions, not module-level constants: importing this module never
touches jax device state.  The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import so these meshes build on the CPU container; on real hardware the
same factories lay the axes out over the actual ICI topology.

Axis semantics:
  pod   — outer data-parallel axis across pods (gradient all-reduce and
          optimizer sharding cross DCN/ICI links between pods)
  data  — in-pod data parallelism / FSDP (params' embed dims sharded)
  model — tensor parallelism (vocab/heads/mlp/experts/ssm)
"""
from __future__ import annotations

import math

import jax


def _mk(shape, axes):
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:          # older jax: meshes are Auto-typed only
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...] | None = None):
    """Elastic mesh factory: any (pods, data, model) shape.  1-sized
    leading axes are squeezed so the same code serves 1..N pods."""
    if axes is None:
        axes = ("pod", "data", "model")[-len(shape):]
    keep = [(s, a) for s, a in zip(shape, axes) if s > 1 or a == "model"]
    if not keep:
        keep = [(1, "data")]
    shape = tuple(s for s, _ in keep)
    axes = tuple(a for _, a in keep)
    return _mk(shape, axes)


def host_mesh():
    """Whatever this process actually has (tests: 1 CPU device)."""
    n = len(jax.devices())
    return _mk((1, n), ("data", "model"))
