"""Supervisor: failure detection -> elastic re-mesh -> restart plan.

The supervisor never touches training state.  It watches heartbeats,
decides *when* to act and *what mesh comes next*; recovery itself is
just "restart the launcher with the new mesh and restore the latest
checkpoint" — the checkpoint layer re-shards to whatever mesh it is
handed (see repro.checkpoint.store), so failure, stragglers, shrink and
grow all share one code path.

``plan_remesh`` is a pure function so the policy is unit-testable: given
surviving host count and per-host chip count it returns the largest
(pods, data, model) grid that preserves the model axis (TP degree is a
property of the model, not the fleet) and keeps the data axis a
power-of-two divisor of the surviving chips.
"""
from __future__ import annotations

import dataclasses
import time

from .heartbeat import read_heartbeats, stale_hosts
from .straggler import StragglerMonitor


def plan_remesh(alive_chips: int, model_parallel: int,
                chips_per_pod: int = 256) -> tuple[int, int, int] | None:
    """-> (pods, data, model) or None if not enough chips for one TP
    group.  Greedy: keep TP, maximize whole pods, then the data axis."""
    if alive_chips < model_parallel:
        return None
    pods = max(alive_chips // chips_per_pod, 1)
    while pods > 1 and alive_chips // pods < model_parallel:
        pods -= 1
    per_pod = alive_chips // pods
    data = per_pod // model_parallel
    # largest power of two <= data (torus-friendly, divides batches)
    data = 1 << (data.bit_length() - 1) if data else 0
    if data == 0:
        return None
    return (pods, data, model_parallel)


@dataclasses.dataclass
class Supervisor:
    heartbeat_dir: str
    expected_hosts: list[str]
    chips_per_host: int = 4
    model_parallel: int = 16
    timeout_s: float = 60.0
    straggler_factor: float = 1.5
    monitor: StragglerMonitor = None  # type: ignore

    def __post_init__(self):
        if self.monitor is None:
            self.monitor = StragglerMonitor(factor=self.straggler_factor)

    def poll(self, now: float | None = None) -> dict:
        """One supervision round.  Returns an action dict:
        {action: "none"|"remesh", dead: [...], stragglers: [...],
         new_mesh: (pods, data, model) | None}."""
        now = now if now is not None else time.time()
        beats = read_heartbeats(self.heartbeat_dir)
        dead = [h for h in self.expected_hosts if h not in beats]
        dead += stale_hosts(self.heartbeat_dir, self.timeout_s, now)
        dead = sorted(set(dead))
        for host, rec in beats.items():
            if rec.get("step_time_s"):
                self.monitor.observe(host, rec["step_time_s"])
        stragglers = [h for h in self.monitor.stragglers()
                      if h not in dead]
        excluded = sorted(set(dead) | set(stragglers))
        if not excluded:
            return {"action": "none", "dead": [], "stragglers": [],
                    "new_mesh": None}
        alive = [h for h in self.expected_hosts if h not in excluded]
        new_mesh = plan_remesh(len(alive) * self.chips_per_host,
                               self.model_parallel)
        return {"action": "remesh" if new_mesh else "halt",
                "dead": dead, "stragglers": stragglers,
                "alive_hosts": alive, "new_mesh": new_mesh}
