"""Per-host heartbeat files: liveness without a coordinator.

Each host writes ``hb_<host>.json`` (step, wall time, step-time EMA)
every step; any reader — the supervisor, a peer, an external watchdog —
decides liveness from file mtimes alone.  On a real cluster the
directory lives on the shared checkpoint filesystem; no extra service
is needed, which matters at 1000+ nodes where "the monitoring system is
down" must not take training with it.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import time


@dataclasses.dataclass
class Heartbeat:
    directory: str
    host: str = "host0"

    def __post_init__(self):
        pathlib.Path(self.directory).mkdir(parents=True, exist_ok=True)

    @property
    def path(self) -> pathlib.Path:
        return pathlib.Path(self.directory) / f"hb_{self.host}.json"

    def beat(self, step: int, step_time_s: float | None = None,
             now: float | None = None):
        rec = {"host": self.host, "step": step,
               "time": now if now is not None else time.time(),
               "step_time_s": step_time_s}
        tmp = self.path.with_suffix(".tmp")
        tmp.write_text(json.dumps(rec))
        tmp.rename(self.path)

    def last(self) -> dict | None:
        """This host's most recent beat record (None if never beaten
        or the file is torn) — the serve engine surfaces it through
        ``stats()`` so an external probe and the engine agree on what
        liveness means."""
        try:
            return json.loads(self.path.read_text())
        except (OSError, json.JSONDecodeError):
            return None

    def age_s(self, now: float | None = None) -> float | None:
        """Seconds since the last beat (None if never beaten)."""
        rec = self.last()
        if rec is None:
            return None
        return (now if now is not None else time.time()) - rec["time"]


def read_heartbeats(directory) -> dict[str, dict]:
    out = {}
    d = pathlib.Path(directory)
    if not d.exists():
        return out
    for p in d.glob("hb_*.json"):
        try:
            rec = json.loads(p.read_text())
            out[rec["host"]] = rec
        except (json.JSONDecodeError, KeyError):
            continue  # torn write: treat as missing this round
    return out


def stale_hosts(directory, timeout_s: float,
                now: float | None = None) -> list[str]:
    """Hosts whose last beat is older than timeout_s."""
    now = now if now is not None else time.time()
    beats = read_heartbeats(directory)
    return sorted(h for h, rec in beats.items()
                  if now - rec["time"] > timeout_s)
