from .heartbeat import Heartbeat, read_heartbeats, stale_hosts
from .straggler import StragglerMonitor
from .supervisor import Supervisor, plan_remesh

__all__ = ["Heartbeat", "read_heartbeats", "stale_hosts",
           "StragglerMonitor", "Supervisor", "plan_remesh"]
