"""Straggler detection from per-host step times.

A host is a straggler when its step-time EMA exceeds ``factor`` x the
fleet median.  Mitigation is the supervisor's call: at small excess it
logs; at persistent excess it excludes the host and triggers an elastic
re-mesh (checkpoint restore re-shards, see repro.checkpoint) — the same
path as a hard failure, which keeps the recovery machinery singular.

The serving engine reuses the same monitor with a different notion of
"host": each batch *bucket* is one observed population of wave times,
so a bucket whose waves run anomalously slow (an artificial straggler
in the chaos tests, a pathological shape in production) surfaces in
``ConvServeEngine.stats()["stragglers"]`` without any serving-specific
detection code.
"""
from __future__ import annotations

import dataclasses
import statistics


@dataclasses.dataclass
class StragglerMonitor:
    factor: float = 1.5
    ema_alpha: float = 0.3
    min_samples: int = 3
    _ema: dict = dataclasses.field(default_factory=dict)
    _count: dict = dataclasses.field(default_factory=dict)

    def observe(self, host: str, step_time_s: float):
        prev = self._ema.get(host)
        self._ema[host] = (step_time_s if prev is None else
                           self.ema_alpha * step_time_s
                           + (1 - self.ema_alpha) * prev)
        self._count[host] = self._count.get(host, 0) + 1

    def stragglers(self) -> list[str]:
        ready = {h: v for h, v in self._ema.items()
                 if self._count.get(h, 0) >= self.min_samples}
        if len(ready) < 2:
            return []
        med = statistics.median(ready.values())
        return sorted(h for h, v in ready.items()
                      if v > self.factor * med)

    def ema(self, host: str) -> float | None:
        """The step-time EMA observed for one host (None if never
        observed)."""
        return self._ema.get(host)

    def fleet_summary(self) -> dict:
        if not self._ema:
            return {}
        vals = list(self._ema.values())
        return {"median_s": statistics.median(vals),
                "max_s": max(vals), "hosts": len(vals)}
