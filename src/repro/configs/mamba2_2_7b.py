"""mamba2-2.7b [ssm] — 64L d_model=2560 attention-free, d_ff=0,
vocab=50280, SSD with d_state=128, headdim=64, expand=2 (d_inner=5120,
80 heads).  [arXiv:2405.21060; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=50280,
    d_head=1,
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_chunk=256,
    tie_embeddings=True,
)
