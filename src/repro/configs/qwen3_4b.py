"""qwen3-4b [dense] — 36L d_model=2560 32H (GQA kv=8) d_ff=9728
vocab=151936, qk-norm, head_dim=128, tied embeddings.
[hf:Qwen/Qwen3-8B; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-4b",
    family="dense",
    n_layers=36,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    d_ff=9728,
    vocab=151936,
    d_head=128,
    qk_norm=True,
    tie_embeddings=True,
    rope_theta=1_000_000.0,
)
