"""Architecture registry: the ten assigned configs + reduced smoke twins.

``get_config(name)``   — the exact published configuration.
``smoke_config(name)`` — a small model of the same family/topology for
                         CPU tests (same scan period, same block kinds).
``input_specs(...)``   — ShapeDtypeStruct stand-ins for every model
                         input of a (config, shape, mode) cell; nothing
                         is allocated (the dry-run contract).
"""
from __future__ import annotations

import dataclasses
import functools
import importlib

import jax
import jax.numpy as jnp

from repro.models.config import SHAPES, ModelConfig, ShapeConfig

ARCH_IDS = {
    "grok-1-314b": "grok_1_314b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "llama3-405b": "llama3_405b",
    "qwen2-0.5b": "qwen2_0_5b",
    "gemma-2b": "gemma_2b",
    "qwen3-4b": "qwen3_4b",
    "internvl2-26b": "internvl2_26b",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "mamba2-2.7b": "mamba2_2_7b",
    "seamless-m4t-medium": "seamless_m4t_medium",
}
ARCH_NAMES = list(ARCH_IDS)


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{ARCH_IDS[name]}")
    return mod.CONFIG


def smoke_config(name: str) -> ModelConfig:
    """Reduced same-family config: runs a forward/train step on CPU."""
    cfg = get_config(name)
    period = cfg.scan_period()
    experts = 0 if cfg.moe_experts == 0 else min(cfg.moe_experts, 8)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=period * (2 if period == 1 else 1),
        d_model=128,
        n_heads=0 if cfg.n_heads == 0 else 4,
        n_kv_heads=0 if cfg.n_heads == 0 else min(max(cfg.n_kv_heads, 1), 2),
        d_head=0 if cfg.n_heads == 0 else 32,
        d_ff=0 if cfg.d_ff == 0 else 256,
        vocab=1024,
        moe_experts=experts,
        moe_top_k=min(cfg.moe_top_k, experts) if experts else 0,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_headdim=1 if cfg.ssm_headdim == 1 else 8,
        ssm_chunk=32,
        enc_layers=2 if cfg.enc_layers else 0,
        num_prefix=8 if cfg.num_prefix else 0,
        frontend_dim=48 if cfg.frontend_dim else 0,
    )


SMOKE_SHAPE = ShapeConfig("smoke", 64, 2, "train")
SMOKE_DECODE = ShapeConfig("smoke_decode", 64, 2, "decode")


# ---------------------------------------------------------------------------
# Input specs (abstract batches) per (config, shape, mode)
# ---------------------------------------------------------------------------
def _per_shard_f32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, *, train: bool):
    """Token batch as ShapeDtypeStructs (the data-pipeline contract)."""
    B, S = shape.global_batch, shape.seq_len
    specs = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    if train:
        specs["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    if cfg.frontend != "none" and cfg.family != "encdec":
        specs["prefix"] = _per_shard_f32((B, cfg.num_prefix,
                                          cfg.frontend_dim))
    if cfg.family == "encdec":
        specs["frames"] = _per_shard_f32((B, S, cfg.frontend_dim))
    return specs


def cache_specs(cfg: ModelConfig, shape: ShapeConfig, dtype=jnp.bfloat16):
    """Abstract KV/SSM cache for a decode cell (nothing allocated)."""
    from repro.models.transformer import init_cache
    B = shape.global_batch
    max_len = shape.seq_len + cfg.num_prefix
    enc_len = shape.seq_len if cfg.family == "encdec" else 0
    return jax.eval_shape(
        functools.partial(init_cache, cfg, B, max_len,
                          enc_len=enc_len, dtype=dtype))


def decode_specs(cfg: ModelConfig, shape: ShapeConfig):
    """Inputs of one serve_step: (token, pos, cache)."""
    B = shape.global_batch
    return {
        "token": jax.ShapeDtypeStruct((B,), jnp.int32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
        "cache": cache_specs(cfg, shape),
    }


def input_specs(cfg: ModelConfig, shape: ShapeConfig, mode: str):
    """mode: train | prefill | decode."""
    if mode == "train":
        return {"batch": batch_specs(cfg, shape, train=True)}
    if mode == "prefill":
        return {"batch": batch_specs(cfg, shape, train=False)}
    if mode == "decode":
        return decode_specs(cfg, shape)
    raise ValueError(mode)
