"""internvl2-26b [vlm] — 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92553; InternViT frontend is a STUB: ``input_specs()`` supplies
precomputed patch embeddings [B, 512, 3200] which the model projects and
prepends to the token sequence.  [arXiv:2404.16821; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=92553,
    d_head=128,
    frontend="vit_stub",
    num_prefix=512,
    frontend_dim=3200,
)
