"""seamless-m4t-medium [audio] — enc-dec, 12L decoder + 12L encoder,
d_model=1024 16H (kv=16) d_ff=4096 vocab=256206.  The speech frontend
(w2v-BERT feature extractor) is a STUB: ``input_specs()`` supplies
precomputed frame embeddings [B, S, 1024] as encoder input.
[arXiv:2308.11596; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=256206,
    enc_layers=12,
    frontend="audio_stub",
    frontend_dim=1024,
)
