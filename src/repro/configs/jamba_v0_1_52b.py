"""jamba-v0.1-52b [hybrid] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16 experts top-2 on every other layer, Mamba:attention
1:7 interleave (one attention layer per 8-layer block), Mamba-1-style
SSM (d_state=16, headdim=1 reproduces per-channel dt).
[arXiv:2403.19887; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=65536,
    d_head=128,
    moe_experts=16,
    moe_top_k=2,
    moe_layer_period=2,
    moe_layer_offset=1,
    attn_layer_period=8,
    attn_layer_offset=4,
    ssm_state=16,
    ssm_headdim=1,
    ssm_expand=2,
    ssm_conv=4,
    ssm_chunk=128,
)
