"""PartitionSpec derivation for every tree that crosses the jit boundary.

Parameters (and optimizer moments) take their specs from the schema's
logical axes.  Batches shard their leading batch dim over the data(+pod)
axes.  Caches are matched structurally by leaf name: KV caches shard
batch over data and heads over model, falling back to sequence sharding
over "data" when batch is too small to split (the long-context decode
cells — GSPMD then lowers row softmax as flash-decode partials + a
combine collective).
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec

from repro.models.config import ModelConfig
from repro.models.schema import Rules, logical_spec, make_rules, pspecs

_CACHE_AXES = {
    "k": ("layers", "batch", "kvseq", "kvheads", None),
    "v": ("layers", "batch", "kvseq", "kvheads", None),
    "ck": ("layers", "batch", "kvseq", "kvheads", None),
    "cv": ("layers", "batch", "kvseq", "kvheads", None),
    "conv": ("layers", "batch", None, "ssm"),
    "ssd": ("layers", "batch", "ssm", None, None),
}


def state_pspecs(schema, rules: Rules):
    """Specs for {params, opt{m,v}, step} given the params schema."""
    p = pspecs(schema, rules)
    return {"params": p, "opt": {"m": p, "v": p},
            "step": PartitionSpec()}


def batch_pspecs(batch_tree, rules: Rules):
    def leaf(x):
        axes = ("batch",) + (None,) * (len(x.shape) - 1)
        return logical_spec(rules, *axes, dims=x.shape)
    return jax.tree.map(leaf, batch_tree)


def cache_pspecs(cache_tree, rules: Rules):
    def walk(tree):
        out = {}
        for name, sub in tree.items():
            if isinstance(sub, dict):
                out[name] = walk(sub)
            else:
                axes = _CACHE_AXES[name]
                out[name] = logical_spec(rules, *axes, dims=sub.shape)
        return out
    return walk(cache_tree)


def named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, PartitionSpec))


def rules_for(mesh, cfg: ModelConfig | None = None) -> Rules:
    return make_rules(mesh)
