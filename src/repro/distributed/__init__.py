from .sharding import (batch_pspecs, cache_pspecs, named, state_pspecs)

__all__ = ["batch_pspecs", "cache_pspecs", "state_pspecs", "named"]
