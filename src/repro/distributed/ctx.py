"""Trace-time activation-sharding context.

Production GSPMD programs do not leave activation layouts to sharding
propagation: every major activation gets an explicit
``with_sharding_constraint`` anchor (the MaxText/Megatron recipe).
Model code calls ``constrain(x, *logical_axes)``; the launcher installs
the logical->mesh rules for the current mesh/phase before tracing.
Outside a launcher (unit tests, CPU examples) the context is empty and
``constrain`` is the identity, so model code never depends on a mesh.

Logical activation axes (resolved by repro.models.schema.Rules with
per-dim divisibility fallback to replication):

  batch    -> ("pod","data")   activation batch dim
  act_seq  -> "model"          sequence parallelism for the residual
                               stream (train/prefill; decode's seq=1
                               auto-replicates via divisibility)
  qheads/kvheads/qgroups/mlp/ssm/experts/vocab -> "model" tensor
                               parallelism inside attention/FFN/SSD
"""
from __future__ import annotations

import contextlib

import jax

_RULES = [None]


def set_act_rules(rules) -> None:
    _RULES[0] = rules


def get_act_rules():
    return _RULES[0]


@contextlib.contextmanager
def act_rules(rules):
    prev = _RULES[0]
    _RULES[0] = rules
    try:
        yield
    finally:
        _RULES[0] = prev


def constrain(x, *axes):
    """Anchor activation `x` to its logical sharding (no-op when no
    rules are installed)."""
    rules = _RULES[0]
    if rules is None:
        return x
    from repro.models.schema import logical_spec
    spec = logical_spec(rules, *axes, dims=x.shape)
    return jax.lax.with_sharding_constraint(x, spec)
