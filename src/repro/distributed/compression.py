"""Gradient compression for the cross-pod all-reduce.

At 2+ pods the `pod` axis all-reduce crosses the slowest links, and
gradients tolerate aggressive quantization when the quantization error
is *fed back* (error-feedback / EF-SGD): each step sends int8 codes with
a per-tensor scale and accumulates the residual locally, so the bias
vanishes over steps and convergence matches f32 all-reduce to first
order.

``compressed_psum`` is the shard_map-side primitive (quantize ->
psum -> dequantize) and ``compress_grads``/``make_error_feedback`` the
step-level wrapper the train loop uses: grads are DP-synced in int8
(4x fewer bytes than f32 on the wire), with stochastic rounding driven
by a per-step key so the compression itself stays unbiased.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x, key=None):
    """f32 -> (int8 codes, f32 scale).  Symmetric per-tensor scaling;
    stochastic rounding when a key is supplied."""
    x = x.astype(jnp.float32)
    amax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12)
    scale = amax / 127.0
    y = x / scale
    if key is not None:
        y = y + jax.random.uniform(key, y.shape, minval=-0.5, maxval=0.5)
    codes = jnp.clip(jnp.round(y), -127, 127).astype(jnp.int8)
    return codes, scale


def dequantize_int8(codes, scale):
    return codes.astype(jnp.float32) * scale


def compressed_psum(x, axis_name: str, key=None):
    """int8-compressed psum over `axis_name` (call inside shard_map).

    Scales are maxed across the group so codes are commensurable; the
    integer sum is exact in int32 (<= 127 * group_size per element)."""
    amax = jax.lax.pmax(jnp.max(jnp.abs(x.astype(jnp.float32))),
                        axis_name)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    y = x.astype(jnp.float32) / scale
    if key is not None:
        y = y + jax.random.uniform(key, y.shape, minval=-0.5, maxval=0.5)
    codes = jnp.clip(jnp.round(y), -127, 127).astype(jnp.int8)
    total = jax.lax.psum(codes.astype(jnp.int32), axis_name)
    return total.astype(jnp.float32) * scale


def make_error_feedback(grads_like):
    """Initial error-feedback residual state (zeros like grads)."""
    return jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)


def compress_grads(grads, ef_state, key=None):
    """One EF round *without* the collective (unit-testable core):
    returns (decoded grads as the receiver sees them, new ef_state)."""
    leaves, treedef = jax.tree.flatten(grads)
    ef_leaves = jax.tree.leaves(ef_state)
    keys = (jax.random.split(key, len(leaves)) if key is not None
            else [None] * len(leaves))
    outs, new_ef = [], []
    for g, e, k in zip(leaves, ef_leaves, keys):
        corrected = g.astype(jnp.float32) + e
        codes, scale = quantize_int8(corrected, k)
        decoded = dequantize_int8(codes, scale)
        outs.append(decoded)
        new_ef.append(corrected - decoded)
    return (jax.tree.unflatten(treedef, outs),
            jax.tree.unflatten(treedef, new_ef))


def wire_bytes(grads, compressed: bool) -> int:
    leaves = jax.tree.leaves(grads)
    if compressed:
        return sum(l.size * 1 + 4 for l in leaves)
    return sum(l.size * 4 for l in leaves)
