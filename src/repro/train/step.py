"""Train-step builder: remat'd model, microbatch gradient accumulation,
AdamW, metrics.

Microbatching is a ``lax.scan`` over batch slices accumulating f32
gradients — the activation working set shrinks by the accumulation
factor while arithmetic intensity per microbatch is unchanged.  The
giant dry-run cells (405B dense / 314B MoE at 1M tokens per step) rely
on this to fit the per-device activation budget; see EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.models import lm_loss, model_schema
from repro.models.config import ModelConfig
from repro.models.schema import abstract_params, init_params
from repro.optim import OptConfig, adamw_init, adamw_update


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: OptConfig = OptConfig()
    n_micro: int = 1            # gradient-accumulation factor
    aux_weight: float = 0.01    # MoE load-balance loss weight
    grad_dtype: str = "float32"  # accumulation buffer; bf16 halves the
    #                              persistent grad footprint (giant cells)


def init_state(cfg: ModelConfig, tc: TrainConfig, key):
    params = init_params(model_schema(cfg), key)
    return {"params": params, "opt": adamw_init(params, tc.opt),
            "step": jnp.zeros((), jnp.int32)}


def abstract_state(cfg: ModelConfig, tc: TrainConfig):
    params = abstract_params(model_schema(cfg))
    moments = jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape,
                                       jnp.dtype(tc.opt.moment_dtype)),
        params)
    return {"params": params, "opt": {"m": moments, "v": moments},
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def train_state_specs(cfg: ModelConfig, rules):
    from repro.distributed.sharding import state_pspecs
    return state_pspecs(model_schema(cfg), rules)


def _split_micro(batch, n):
    return jax.tree.map(
        lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]), batch)


def make_train_step(cfg: ModelConfig, tc: TrainConfig):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def loss_fn(params, mb):
        return lm_loss(params, mb, cfg, aux_weight=tc.aux_weight)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state, batch):
        params = state["params"]
        if tc.n_micro == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            micro = _split_micro(batch, tc.n_micro)
            gdt = jnp.dtype(tc.grad_dtype)
            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, gdt), params)

            def acc(carry, mb):
                gsum, lsum = carry
                (l, _), g = grad_fn(params, mb)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(gdt), gsum, g)
                return (gsum, lsum + l), None

            (grads, loss), _ = jax.lax.scan(
                acc, (g0, jnp.float32(0.0)), micro)
            inv = 1.0 / tc.n_micro
            grads = jax.tree.map(lambda g: g * inv, grads)
            loss = loss * inv
            metrics = {"xent": loss, "aux": jnp.float32(0.0)}

        new_params, new_opt, opt_metrics = adamw_update(
            params, grads, state["opt"], state["step"], tc.opt)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return new_state, metrics

    return train_step
