from .step import TrainConfig, init_state, make_train_step, train_state_specs

__all__ = ["TrainConfig", "make_train_step", "init_state",
           "train_state_specs"]
