"""Oracles for the HOBFLOPS convolution."""
from __future__ import annotations

import numpy as np

from repro.core import softfloat as sf
from repro.core.fpformat import RNE, FPFormat
from repro.kernels.bitslice_mac.ref import hobflops_matmul_ref


def conv2d_f32(images, kernels, stride: int = 1, padding: str = "SAME"):
    """Plain float conv oracle (numpy, NHWC x HWIO -> NHWC)."""
    import jax
    import jax.numpy as jnp
    out = jax.lax.conv_general_dilated(
        jnp.asarray(images), jnp.asarray(kernels),
        window_strides=(stride, stride), padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return np.asarray(out)


def hobflops_conv2d_ref(images, kernels, fmt: FPFormat, stride: int = 1,
                        padding: str = "SAME", extended: bool = False,
                        rounding: str = RNE, relu: bool = False):
    """Sequential HOBFLOPS conv oracle via im2col + code-level MAC."""
    from repro.kernels.conv2d_bitslice.ops import im2col
    kh, kw, C, M = kernels.shape
    patches = np.asarray(im2col(images, kh, kw, stride, padding),
                         np.float64)
    B, Ho, Wo, K = patches.shape
    ic = sf.encode(patches.reshape(-1, K), fmt, rounding)
    wc = sf.encode(np.asarray(kernels, np.float64).reshape(K, M), fmt,
                   rounding)
    out_codes = hobflops_matmul_ref(ic, wc, fmt, extended, rounding)
    fmt_out = fmt.mult_out(extended)
    vals = sf.decode(out_codes, fmt_out)
    if relu:
        vals = np.maximum(vals, 0.0)
    return vals.reshape(B, Ho, Wo, M)
