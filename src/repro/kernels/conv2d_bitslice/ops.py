"""The paper's CNN convolution on the bitslice-parallel HOBFLOPS MAC.

Convolution is lowered to the verified bitslice GEMM by im2col: IFM
patches [B*Ho*Wo, kh*kw*C] against kernels [kh*kw*C, M] (the paper's
Fig. 5 layout with LANES of kernels per bitslice word).  ReLU runs *in
the HOBFLOPS domain* as one bitwise op per plane: clearing every plane
where the sign plane is set maps negative values to the canonical +0
code (exc=00) — activation for free inside the bitslice pipeline,
exactly the "data stays in HOBFLOPS format between layers" flow of
paper §3.4.
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp

from repro.core.fpformat import RNE, FPFormat
from repro.kernels.bitslice_mac.kernel import bitslice_mac_pallas
from repro.kernels.bitslice_mac.ops import (LANE, _bitslice_mac_jnp,
                                            encode_inputs)


def im2col(images, kh: int, kw: int, stride: int = 1,
           padding: str = "SAME"):
    """[B, H, W, C] -> patches [B, Ho, Wo, kh*kw*C]."""
    B, H, W, C = images.shape
    if padding == "SAME":
        pad_h = max((-(-H // stride) - 1) * stride + kh - H, 0)
        pad_w = max((-(-W // stride) - 1) * stride + kw - W, 0)
    else:
        pad_h = pad_w = 0
    x = jnp.pad(images, ((0, 0), (pad_h // 2, pad_h - pad_h // 2),
                         (pad_w // 2, pad_w - pad_w // 2), (0, 0)))
    Ho = (x.shape[1] - kh) // stride + 1
    Wo = (x.shape[2] - kw) // stride + 1
    cols = []
    for i in range(kh):
        for j in range(kw):
            cols.append(jax.lax.slice(
                x, (0, i, j, 0),
                (B, i + (Ho - 1) * stride + 1, j + (Wo - 1) * stride + 1,
                 C), (1, stride, stride, 1)))
    return jnp.concatenate(cols, axis=-1).reshape(B, Ho, Wo, kh * kw * C)


def hobflops_relu_planes(planes, fmt: FPFormat):
    """OFM bit planes [NOUT, ...] -> ReLU'd planes: negative values
    become the all-zero (+0, exc=00) code.  One ANDN per plane."""
    sign = planes[fmt.sign_off]
    keep = ~sign
    return planes & keep[None]


def derive_blocks(P: int, K: int, M: int, *, p_block: int | None = None,
                  m_block: int | None = None, c_block: int | None = None,
                  c_unroll: int | None = None) -> dict:
    """Launch parameters for a [P, K] @ [K, M] bitslice GEMM.

    Defaults follow the TPU vreg geometry: 8 sublanes of output pixels
    per tile (``p_block``), up to 128 int32 lane words of kernels
    (``m_block`` — *not* 1, and never padding M past the next lane-word
    multiple), the full reduction in VMEM when it fits (``c_block``) and
    4 chained channels per netlist call (``c_unroll``).  Every value is
    clamped to the problem size; explicit arguments win (the autotune
    sweep passes candidates through here).  See DESIGN.md §5.
    """
    m_words = -(-M // LANE)
    blocks = {
        "p_block": min(p_block or 8, P),
        "m_block": min(m_block or 128, m_words),
        "c_block": min(c_block or 64, K),
        "c_unroll": c_unroll or 4,
    }
    blocks["c_unroll"] = max(1, min(blocks["c_unroll"], blocks["c_block"]))
    while blocks["c_block"] % blocks["c_unroll"]:
        blocks["c_unroll"] -= 1
    return blocks


@functools.partial(jax.jit, static_argnames=(
    "fmt", "kh", "kw", "stride", "padding", "extended", "rounding",
    "relu", "backend", "interpret", "p_block", "m_block", "c_block",
    "c_unroll"))
def hobflops_conv2d(images, kernels, *, fmt: FPFormat, stride: int = 1,
                    padding: str = "SAME", extended: bool = False,
                    rounding: str = RNE, relu: bool = False,
                    backend: str = "jnp", interpret: bool = False,
                    kh: int | None = None, kw: int | None = None,
                    p_block: int | None = None, m_block: int | None = None,
                    c_block: int | None = None, c_unroll: int | None = None):
    """images [B,H,W,C] f32, kernels [kh,kw,C,M] f32 -> [B,Ho,Wo,M] f32
    computed entirely in HOBFLOPS bitslice arithmetic.

    Block sizes / ``c_unroll`` default to shape-derived values
    (:func:`derive_blocks`) and are exposed for autotuning
    (:func:`tune_conv_blocks`)."""
    khh, kww, C, M = kernels.shape
    patches = im2col(images, khh, kww, stride, padding)
    B, Ho, Wo, K = patches.shape
    pf = patches.reshape(B * Ho * Wo, K)
    wf = kernels.reshape(K, M)

    from repro.core import softfloat as sf
    from repro.core.bitslice import unpack_planes
    blk = derive_blocks(B * Ho * Wo, K, M, p_block=p_block,
                        m_block=m_block, c_block=c_block,
                        c_unroll=c_unroll)
    i_masks, w_planes = encode_inputs(
        pf, wf, fmt, rounding, p_block=blk["p_block"],
        m_block=blk["m_block"], c_block=blk["c_block"])
    if backend == "pallas":
        out = bitslice_mac_pallas(i_masks, w_planes, fmt=fmt,
                                  extended=extended, rounding=rounding,
                                  interpret=interpret, **blk)
    else:
        out = _bitslice_mac_jnp(i_masks, w_planes, fmt=fmt,
                                extended=extended, rounding=rounding,
                                c_unroll=blk["c_unroll"])
    fmt_out = fmt.mult_out(extended)
    if relu:
        out = hobflops_relu_planes(out, fmt_out)
    codes = unpack_planes(out)
    vals = sf.decode_jnp(codes, fmt_out)
    return vals[:B * Ho * Wo, :M].reshape(B, Ho, Wo, M)


def tune_conv_blocks(images, kernels, *, fmt: FPFormat,
                     backend: str = "jnp", interpret: bool = False,
                     candidates=None, iters: int = 2, **conv_kw):
    """Small sweep helper: time ``hobflops_conv2d`` over block-size /
    ``c_unroll`` candidates and return ``(best_blocks, results)``.

    ``candidates`` is an iterable of dicts with any of
    ``p_block/m_block/c_block/c_unroll`` set (missing keys fall back to
    the derived defaults); by default a c_unroll x m_block cross sweep.
    ``results`` maps the *resolved* (post-clamp) parameter tuple to
    seconds/call — candidates that clamp to the same launch config are
    timed once.  Raises if every candidate fails to launch.
    """
    if candidates is None:
        candidates = [{"c_unroll": u, "m_block": m}
                      for u in (1, 2, 4, 8) for m in (8, 32, 128)]
    khh, kww, C, M = kernels.shape
    B, H, W, _ = images.shape
    results: dict[tuple, float] = {}
    best, best_dt = None, float("inf")
    last_err = None
    for cand in candidates:
        # Resolve through the same clamping the launch will apply so
        # equivalent candidates dedupe (P is conservatively the
        # unstrided patch count; exact P only shifts p_block clamping).
        key = tuple(sorted(derive_blocks(B * H * W, khh * kww * C, M,
                                         **cand).items()))
        if key in results:
            continue
        run = lambda: jax.block_until_ready(hobflops_conv2d(
            images, kernels, fmt=fmt, backend=backend,
            interpret=interpret, **cand, **conv_kw))
        try:
            run()                                   # compile + warm
            t0 = time.perf_counter()
            for _ in range(iters):
                run()
            dt = (time.perf_counter() - t0) / iters
        except Exception as e:                      # unlaunchable combo
            last_err = e
            continue
        results[key] = dt
        if dt < best_dt:
            best, best_dt = dict(cand), dt
    if best is None:
        raise RuntimeError(
            f"tune_conv_blocks: no candidate launched") from last_err
    return best, results
