"""The paper's CNN convolution on the bitslice-parallel HOBFLOPS MAC.

Convolution is lowered to the verified bitslice GEMM by im2col: IFM
patches [B*Ho*Wo, kh*kw*C] against kernels [kh*kw*C, M] (the paper's
Fig. 5 layout with LANES of kernels per bitslice word).  ReLU runs *in
the HOBFLOPS domain* as one bitwise op per plane: clearing every plane
where the sign plane is set maps negative values to the canonical +0
code (exc=00) — activation for free inside the bitslice pipeline,
exactly the "data stays in HOBFLOPS format between layers" flow of
paper §3.4.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.fpformat import RNE, FPFormat
from repro.kernels.bitslice_mac.kernel import bitslice_mac_pallas
from repro.kernels.bitslice_mac.ops import (_bitslice_mac_jnp,
                                            encode_inputs)


def im2col(images, kh: int, kw: int, stride: int = 1,
           padding: str = "SAME"):
    """[B, H, W, C] -> patches [B, Ho, Wo, kh*kw*C]."""
    B, H, W, C = images.shape
    if padding == "SAME":
        pad_h = max((-(-H // stride) - 1) * stride + kh - H, 0)
        pad_w = max((-(-W // stride) - 1) * stride + kw - W, 0)
    else:
        pad_h = pad_w = 0
    x = jnp.pad(images, ((0, 0), (pad_h // 2, pad_h - pad_h // 2),
                         (pad_w // 2, pad_w - pad_w // 2), (0, 0)))
    Ho = (x.shape[1] - kh) // stride + 1
    Wo = (x.shape[2] - kw) // stride + 1
    cols = []
    for i in range(kh):
        for j in range(kw):
            cols.append(jax.lax.slice(
                x, (0, i, j, 0),
                (B, i + (Ho - 1) * stride + 1, j + (Wo - 1) * stride + 1,
                 C), (1, stride, stride, 1)))
    return jnp.concatenate(cols, axis=-1).reshape(B, Ho, Wo, kh * kw * C)


def hobflops_relu_planes(planes, fmt: FPFormat):
    """OFM bit planes [NOUT, ...] -> ReLU'd planes: negative values
    become the all-zero (+0, exc=00) code.  One ANDN per plane."""
    sign = planes[fmt.sign_off]
    keep = ~sign
    return planes & keep[None]


@functools.partial(jax.jit, static_argnames=(
    "fmt", "kh", "kw", "stride", "padding", "extended", "rounding",
    "relu", "backend", "interpret"))
def hobflops_conv2d(images, kernels, *, fmt: FPFormat, stride: int = 1,
                    padding: str = "SAME", extended: bool = False,
                    rounding: str = RNE, relu: bool = False,
                    backend: str = "jnp", interpret: bool = False,
                    kh: int | None = None, kw: int | None = None):
    """images [B,H,W,C] f32, kernels [kh,kw,C,M] f32 -> [B,Ho,Wo,M] f32
    computed entirely in HOBFLOPS bitslice arithmetic."""
    khh, kww, C, M = kernels.shape
    patches = im2col(images, khh, kww, stride, padding)
    B, Ho, Wo, K = patches.shape
    pf = patches.reshape(B * Ho * Wo, K)
    wf = kernels.reshape(K, M)

    from repro.core import softfloat as sf
    from repro.core.bitslice import unpack_planes
    i_masks, w_planes = encode_inputs(pf, wf, fmt, rounding)
    if backend == "pallas":
        out = bitslice_mac_pallas(i_masks, w_planes, fmt=fmt,
                                  extended=extended, rounding=rounding,
                                  p_block=min(8, i_masks.shape[0]),
                                  m_block=1, c_block=min(64, K),
                                  interpret=interpret)
    else:
        out = _bitslice_mac_jnp(i_masks, w_planes, fmt=fmt,
                                extended=extended, rounding=rounding)
    fmt_out = fmt.mult_out(extended)
    if relu:
        out = hobflops_relu_planes(out, fmt_out)
    codes = unpack_planes(out)
    vals = sf.decode_jnp(codes, fmt_out)
    return vals[:B * Ho * Wo, :M].reshape(B, Ho, Wo, M)
