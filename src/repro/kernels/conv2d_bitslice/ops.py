"""The paper's CNN convolution on the bitslice-parallel HOBFLOPS MAC.

Convolution is lowered to the verified bitslice GEMM by im2col: IFM
patches [B*Ho*Wo, kh*kw*C] against kernels [kh*kw*C, M] (the paper's
Fig. 5 layout with LANES of kernels per bitslice word).  ReLU runs *in
the HOBFLOPS domain* as one bitwise op per plane: clearing every plane
where the sign plane is set maps negative values to the canonical +0
code (exc=00) — activation for free inside the bitslice pipeline.

The layer is split into explicit stages so multi-layer networks stay in
the bitslice domain between layers — the "data stays in HOBFLOPS format
between layers" flow of paper §3.4, realized end-to-end by
``conv2d_bitslice.network.HobflopsNetwork`` (DESIGN.md §8):

* :func:`encode_activations`   — f32 NHWC -> :class:`BitsliceActivation`
* :func:`conv_core`            — activation x ConvWeights -> activation
                                 (plane-domain im2col + bitslice MAC
                                 + in-domain ReLU)
* :func:`cast_activations`     — accumulator-format planes -> next
                                 layer's operand format, via the
                                 optimized ``build_cast`` netlist
* :func:`decode_activations`   — activation -> f32 NHWC

``hobflops_conv2d`` composes encode/conv_core/decode for the one-layer
case and is bit-exact to the seed implementation.
"""
from __future__ import annotations

import dataclasses
import functools
import time

import jax
import jax.numpy as jnp

from repro.core import softfloat as sf
from repro.core.bitslice import (BitsliceActivation, pack_planes,
                                 unpack_planes, window_gather_planes)
from repro.core.fpformat import EXC_INF, RNE, FPFormat
from repro.core.pallas_backend import fused_mac_pallas
from repro.kernels.bitslice_mac.kernel import (add_netlist_fn,
                                               bitslice_mac_pallas,
                                               cast_netlist_fn,
                                               max_netlist_fn,
                                               scale_netlist_fn)
from repro.kernels.bitslice_mac.ops import (LANE, _bitslice_mac_jnp,
                                            _pad_to, encode_weight_planes)


def _conv_pad(H: int, W: int, kh: int, kw: int, stride: int,
              padding: str) -> tuple[int, int]:
    """Total (pad_h, pad_w) applied by :func:`im2col`."""
    if padding == "SAME":
        return (max((-(-H // stride) - 1) * stride + kh - H, 0),
                max((-(-W // stride) - 1) * stride + kw - W, 0))
    return 0, 0


def conv_out_hw(H: int, W: int, kh: int, kw: int, stride: int = 1,
                padding: str = "SAME") -> tuple[int, int]:
    """Output spatial dims of :func:`im2col` (exact, incl. clamped
    SAME padding) — used for launch-parameter derivation and the
    network runner's shape plan."""
    pad_h, pad_w = _conv_pad(H, W, kh, kw, stride, padding)
    return ((H + pad_h - kh) // stride + 1,
            (W + pad_w - kw) // stride + 1)


def im2col(images, kh: int, kw: int, stride: int = 1,
           padding: str = "SAME"):
    """[B, H, W, C] -> patches [B, Ho, Wo, kh*kw*C]."""
    B, H, W, C = images.shape
    pad_h, pad_w = _conv_pad(H, W, kh, kw, stride, padding)
    x = jnp.pad(images, ((0, 0), (pad_h // 2, pad_h - pad_h // 2),
                         (pad_w // 2, pad_w - pad_w // 2), (0, 0)))
    Ho = (x.shape[1] - kh) // stride + 1
    Wo = (x.shape[2] - kw) // stride + 1
    cols = []
    for i in range(kh):
        for j in range(kw):
            cols.append(jax.lax.slice(
                x, (0, i, j, 0),
                (B, i + (Ho - 1) * stride + 1, j + (Wo - 1) * stride + 1,
                 C), (1, stride, stride, 1)))
    return jnp.concatenate(cols, axis=-1).reshape(B, Ho, Wo, kh * kw * C)


def hobflops_relu_planes(planes, fmt: FPFormat):
    """OFM bit planes [NOUT, ...] -> ReLU'd planes.  One ANDN per plane.

    Semantics (pinned by an exhaustive test against the word-parallel
    ``softfloat.fp_relu`` oracle): every code whose *sign bit* is set —
    negative normals, -0, -inf, and any non-canonical sign-set NaN —
    becomes the canonical all-zero +0 code (exc=00); every sign-clear
    code passes through unchanged.  In particular -inf maps to +0 (not
    to a saturated finite value), and NaN propagates iff it is the
    canonical sign-clear NaN the datapaths emit.  This is the
    ``max(x, +0)`` of the FloPoCo encoding up to the NaN convention:
    a true FP max would also map sign-set NaN to NaN, but the datapaths
    never produce one, so the 1-gate-per-plane mask is used instead of
    a ~100-gate ``build_max`` against a +0 constant.
    """
    sign = planes[fmt.sign_off]
    keep = ~sign
    return planes & keep[None]


# ---------------------------------------------------------------------------
# Pre-encoded conv weights (encode static kernels once, reuse per call)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(eq=False)
class ConvWeights:
    """Conv kernels pre-encoded to HOBFLOPS bit planes.

    ``planes`` is ``[kh*kw*cin, NIN, Mw]`` int32 (reduction axis in
    im2col (i, j, c) order, output channels packed along int32 lanes).
    Registered as a JAX pytree — the geometry and format ride in the
    static treedef, so a ConvWeights passes through ``jax.jit``.
    """
    planes: "jnp.ndarray"
    kh: int
    kw: int
    cin: int
    cout: int
    fmt: FPFormat

    def tree_flatten(self):
        return ((self.planes,),
                (self.kh, self.kw, self.cin, self.cout, self.fmt))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], *aux)


jax.tree_util.register_pytree_node(
    ConvWeights, ConvWeights.tree_flatten, ConvWeights.tree_unflatten)


def encode_conv_weights(kernels, fmt: FPFormat,
                        rounding: str = RNE) -> ConvWeights:
    """f32 [kh,kw,C,M] -> :class:`ConvWeights` (encode + bitslice once).

    The planes carry minimal padding (M up to the next lane-word
    multiple only); launch-time block padding happens in
    :func:`conv_core`, so one encoding serves any block configuration.
    """
    kh, kw, C, M = kernels.shape
    planes = encode_weight_planes(jnp.asarray(kernels).reshape(kh * kw * C,
                                                               M),
                                  fmt, rounding, c_block=1, m_block=1)
    return ConvWeights(planes, kh, kw, C, M, fmt)


# ---------------------------------------------------------------------------
# Pipeline stages: encode / im2col-in-planes / conv_core / cast / decode
# ---------------------------------------------------------------------------
def encode_activations(images, fmt: FPFormat, rounding: str = RNE,
                       p_block: int = 8) -> BitsliceActivation:
    """f32 [B,H,W,C] -> bitslice activation (the pipeline's single
    entry encode)."""
    B, H, W, C = images.shape
    codes = sf.encode_jnp(jnp.asarray(images).reshape(B * H * W, C),
                          fmt, rounding)
    codes = _pad_to(codes, min(p_block, B * H * W), 0)
    planes = pack_planes(codes, fmt.nbits)     # pads C to a lane word
    return BitsliceActivation(planes, fmt, (B, H, W, C))


def decode_activations(act: BitsliceActivation):
    """Bitslice activation -> f32 [B,H,W,C] (the single exit decode)."""
    B, H, W, C = act.shape
    codes = unpack_planes(act.planes)          # [P, Mw*LANE]
    vals = sf.decode_jnp(codes, act.fmt)
    return vals[:B * H * W, :C].reshape(B, H, W, C)


def cast_activations(act: BitsliceActivation, dst_fmt: FPFormat,
                     rounding: str = RNE) -> BitsliceActivation:
    """Re-round an activation into ``dst_fmt`` without leaving the
    bitslice domain: the optimized ``build_cast`` netlist runs as a few
    dozen bitwise ops over the plane array.  Bit-exact to
    decode -> f32 -> encode (``softfloat.fp_cast``; tests verify)."""
    if act.fmt == dst_fmt:
        return act
    fn, _ = cast_netlist_fn(act.fmt, dst_fmt, rounding)
    out = fn(x=act.planes)["out"]
    out = jnp.broadcast_to(out, (dst_fmt.nbits,) + act.planes.shape[1:])
    return BitsliceActivation(out, dst_fmt, act.shape)


def activation_patch_masks(act: BitsliceActivation, kh: int, kw: int,
                           stride: int = 1, padding: str = "SAME"):
    """Plane-domain im2col: gather layer-(n+1) IFM patches directly
    from layer-n output planes.

    Expands the channel-lane-packed planes to per-(pixel, channel) 0/-1
    broadcast masks (pure shift/mask ops — no f32 materialization),
    restores the NHWC spatial structure, and gathers kh x kw patches in
    the mask domain.  SAME padding inserts all-zero masks == the +0
    code, the MAC identity.  Returns ``(i_masks [B*Ho*Wo, kh*kw*C, NIN],
    (Ho, Wo))``.
    """
    nb = act.nbits
    B, H, W, C = act.shape
    shifts = jnp.arange(LANE, dtype=jnp.int32)
    bits = (act.planes[:, :, :, None] >> shifts) & 1   # [nb, P, Mw, LANE]
    masks = -bits.reshape(nb, bits.shape[1], -1)[:, :B * H * W, :C]
    masks = jnp.moveaxis(masks, 0, -1)                 # [BHW, C, nb]
    masks = masks.reshape(B, H, W, C * nb)
    pat = im2col(masks, kh, kw, stride, padding)
    _, Ho, Wo, _ = pat.shape
    return pat.reshape(B * Ho * Wo, kh * kw * C, nb), (Ho, Wo)


# ---------------------------------------------------------------------------
# Plane-domain elementwise / pooling ops (the graph runner's node kinds)
# ---------------------------------------------------------------------------
def relu_activations(act: BitsliceActivation) -> BitsliceActivation:
    """In-domain ReLU as a standalone graph node (one ANDN per plane;
    see :func:`hobflops_relu_planes` for the pinned semantics)."""
    return BitsliceActivation(hobflops_relu_planes(act.planes, act.fmt),
                              act.fmt, act.shape)


def _align_rows(a, b):
    """Zero-pad the shorter of two plane arrays along the row axis so
    elementwise netlists can combine activations whose P padding
    differs (zero rows are the +0 code — identity for add, and beyond
    every logical pixel for max)."""
    P = max(a.shape[1], b.shape[1])
    return _pad_to(a, P, 1), _pad_to(b, P, 1)


def add_activations(a: BitsliceActivation, b: BitsliceActivation,
                    fmt: FPFormat | None = None,
                    rounding: str = RNE) -> BitsliceActivation:
    """Elementwise FP add of two activations in the plane domain — the
    residual-merge node.  Branches whose formats differ are first cast
    (``cast_activations``, a no-op on matching formats) to ``fmt``,
    which defaults to the first operand's format; the sum is computed
    by the optimized ``build_add`` netlist at that format."""
    assert a.shape == b.shape, (a.shape, b.shape)
    tgt = fmt or a.fmt
    a = cast_activations(a, tgt, rounding)
    b = cast_activations(b, tgt, rounding)
    pa, pb = _align_rows(a.planes, b.planes)
    fn, _ = add_netlist_fn(tgt, rounding)
    out = fn(x=pa, y=pb)["out"]
    out = jnp.broadcast_to(out, (tgt.nbits,) + pa.shape[1:])
    return BitsliceActivation(out, tgt, a.shape)


def _fold_pairwise(items, combine):
    """Balanced pairwise reduction (the 'add-tree' order); both the
    resident plane path and the word-parallel oracle fold windows with
    this exact shape, so they stay bit-identical even though FP add is
    not associative."""
    items = list(items)
    while len(items) > 1:
        nxt = [combine(items[i], items[i + 1])
               for i in range(0, len(items) - 1, 2)]
        if len(items) % 2:
            nxt.append(items[-1])
        items = nxt
    return items[0]


def _pool_geometry(act: BitsliceActivation, window, stride, padding):
    kh, kw = (window, window) if isinstance(window, int) else window
    stride = stride or kh
    B, H, W, C = act.shape
    pad_h, pad_w = _conv_pad(H, W, kh, kw, stride, padding)
    return kh, kw, stride, pad_h, pad_w


def neg_inf_code(fmt: FPFormat) -> int:
    """The canonical -inf code word — the max identity, used to fill
    SAME-padding slots of a plane-domain maxpool."""
    return (1 << fmt.sign_off) | (EXC_INF << fmt.exc_off)


def maxpool2d_activations(act: BitsliceActivation, window=2,
                          stride: int | None = None,
                          padding: str = "VALID") -> BitsliceActivation:
    """Max pooling entirely inside the bitslice domain.

    Windows are gathered by pure row selection
    (:func:`~repro.core.bitslice.window_gather_planes`; channels stay
    lane-packed) and folded pairwise through the optimized ``build_max``
    netlist — FP compare/select with the :func:`softfloat.fp_max`
    semantics (NaN propagates, -inf loses to everything).  SAME padding
    fills with -inf, the max identity; ``stride`` defaults to the
    window size (non-overlapping pooling)."""
    kh, kw, stride, pad_h, pad_w = _pool_geometry(act, window, stride,
                                                  padding)
    wins, (Ho, Wo) = window_gather_planes(
        act.planes, act.shape, kh, kw, stride, pad_h, pad_w,
        fill_code=neg_inf_code(act.fmt))
    fn, _ = max_netlist_fn(act.fmt)
    nb = act.fmt.nbits

    def combine(x, y):
        return jnp.broadcast_to(fn(x=x, y=y)["out"], (nb,) + x.shape[1:])

    out = _fold_pairwise(list(wins), combine)
    B, _, _, C = act.shape
    return BitsliceActivation(out, act.fmt, (B, Ho, Wo, C))


def avgpool2d_activations(act: BitsliceActivation, window=2,
                          stride: int | None = None,
                          padding: str = "VALID",
                          rounding: str = RNE) -> BitsliceActivation:
    """Average pooling in the bitslice domain: a pairwise ``build_add``
    tree over the window followed by one ``build_scale`` (multiply by
    ``2**-log2(window area)``) — no divider anywhere, so the window
    area must be a power of two.  SAME padding fills with +0 (the add
    identity) and still divides by the full window area
    (count-include-pad semantics); ``stride`` defaults to the window
    size."""
    kh, kw, stride, pad_h, pad_w = _pool_geometry(act, window, stride,
                                                  padding)
    area = kh * kw
    assert area & (area - 1) == 0, \
        f"avgpool window area must be a power of two, got {kh}x{kw}"
    wins, (Ho, Wo) = window_gather_planes(
        act.planes, act.shape, kh, kw, stride, pad_h, pad_w, fill_code=0)
    fn, _ = add_netlist_fn(act.fmt, rounding)
    nb = act.fmt.nbits

    def combine(x, y):
        return jnp.broadcast_to(fn(x=x, y=y)["out"], (nb,) + x.shape[1:])

    summed = _fold_pairwise(list(wins), combine)
    sfn, _ = scale_netlist_fn(act.fmt, area.bit_length() - 1)
    out = jnp.broadcast_to(sfn(x=summed)["out"], summed.shape)
    B, _, _, C = act.shape
    return BitsliceActivation(out, act.fmt, (B, Ho, Wo, C))


def derive_blocks(P: int, K: int, M: int, *, p_block: int | None = None,
                  m_block: int | None = None, c_block: int | None = None,
                  c_unroll: int | None = None) -> dict:
    """Launch parameters for a [P, K] @ [K, M] bitslice GEMM.

    Defaults follow the TPU vreg geometry: 8 sublanes of output pixels
    per tile (``p_block``), up to 128 int32 lane words of kernels
    (``m_block`` — *not* 1, and never padding M past the next lane-word
    multiple), the full reduction in VMEM when it fits (``c_block``) and
    4 chained channels per netlist call (``c_unroll``).  Every value is
    clamped to the problem size; explicit arguments win (the autotune
    sweep passes candidates through here).  See DESIGN.md §5.
    """
    m_words = -(-M // LANE)
    blocks = {
        "p_block": min(p_block or 8, P),
        "m_block": min(m_block or 128, m_words),
        "c_block": min(c_block or 64, K),
        "c_unroll": c_unroll or 4,
    }
    blocks["c_unroll"] = max(1, min(blocks["c_unroll"], blocks["c_block"]))
    while blocks["c_block"] % blocks["c_unroll"]:
        blocks["c_unroll"] -= 1
    return blocks


def conv_core(act: BitsliceActivation, weights: ConvWeights, *,
              stride: int = 1, padding: str = "SAME",
              extended: bool = False, rounding: str = RNE,
              relu: bool = False, backend: str = "jnp",
              interpret: bool = False, p_block: int | None = None,
              m_block: int | None = None, c_block: int | None = None,
              c_unroll: int | None = None) -> BitsliceActivation:
    """One conv layer entirely inside the bitslice domain.

    Consumes an activation in the layer's operand format, performs the
    plane-domain im2col + bitslice MAC (+ in-domain ReLU), and returns
    the OFM activation in the accumulator format
    ``weights.fmt.mult_out(extended)`` — ready to be cast to the next
    layer's operand format by :func:`cast_activations` without touching
    float32.
    """
    assert act.fmt == weights.fmt, (act.fmt, weights.fmt)
    assert act.shape[3] == weights.cin, (act.shape, weights.cin)
    i_masks, (Ho, Wo) = activation_patch_masks(
        act, weights.kh, weights.kw, stride, padding)
    B = act.shape[0]
    P, K, M = B * Ho * Wo, weights.kh * weights.kw * weights.cin, \
        weights.cout
    blk = derive_blocks(P, K, M, p_block=p_block, m_block=m_block,
                        c_block=c_block, c_unroll=c_unroll)
    i_masks = _pad_to(_pad_to(i_masks, blk["p_block"], 0),
                      blk["c_block"], 1)
    w_planes = _pad_to(_pad_to(weights.planes, blk["c_block"], 0),
                       blk["m_block"], 2)
    if backend == "pallas":
        out = bitslice_mac_pallas(i_masks, w_planes, fmt=weights.fmt,
                                  extended=extended, rounding=rounding,
                                  interpret=interpret, **blk)
    elif backend == "pallas_fused":
        # The fused backend absorbs the ReLU epilogue into the kernel
        # (two in-kernel ops on the final C step) — no post-hoc
        # hobflops_relu_planes pass, the whole layer is one pallas_call.
        out = fused_mac_pallas(i_masks, w_planes, fmt=weights.fmt,
                               extended=extended, rounding=rounding,
                               relu=relu, interpret=interpret, **blk)
    else:
        out = _bitslice_mac_jnp(i_masks, w_planes, fmt=weights.fmt,
                                extended=extended, rounding=rounding,
                                c_unroll=blk["c_unroll"])
    fmt_out = weights.fmt.mult_out(extended)
    if relu and backend != "pallas_fused":
        out = hobflops_relu_planes(out, fmt_out)
    return BitsliceActivation(out, fmt_out, (B, Ho, Wo, M))


@functools.partial(jax.jit, static_argnames=(
    "fmt", "kh", "kw", "stride", "padding", "extended", "rounding",
    "relu", "backend", "interpret", "p_block", "m_block", "c_block",
    "c_unroll"))
def hobflops_conv2d(images, kernels, *, fmt: FPFormat, stride: int = 1,
                    padding: str = "SAME", extended: bool = False,
                    rounding: str = RNE, relu: bool = False,
                    backend: str = "jnp", interpret: bool = False,
                    kh: int | None = None, kw: int | None = None,
                    p_block: int | None = None, m_block: int | None = None,
                    c_block: int | None = None, c_unroll: int | None = None):
    """images [B,H,W,C] f32, kernels [kh,kw,C,M] f32 (or a pre-encoded
    :class:`ConvWeights`) -> [B,Ho,Wo,M] f32 computed entirely in
    HOBFLOPS bitslice arithmetic.

    This is the one-layer composition encode -> conv_core -> decode.
    Multi-layer networks should use
    :class:`repro.kernels.conv2d_bitslice.network.HobflopsNetwork`,
    which keeps the interior boundaries in the bitslice domain.

    Block sizes / ``c_unroll`` default to shape-derived values
    (:func:`derive_blocks`) and are exposed for autotuning
    (:func:`tune_conv_blocks`)."""
    if not isinstance(kernels, ConvWeights):
        kernels = encode_conv_weights(kernels, fmt, rounding)
    assert kernels.fmt == fmt, (kernels.fmt, fmt)
    act = encode_activations(images, fmt, rounding)
    out = conv_core(act, kernels, stride=stride, padding=padding,
                    extended=extended, rounding=rounding, relu=relu,
                    backend=backend, interpret=interpret,
                    p_block=p_block, m_block=m_block, c_block=c_block,
                    c_unroll=c_unroll)
    return decode_activations(out)


# Errors that mean "this block-size candidate cannot launch" (shape /
# tiling asserts, Mosaic lowering limits, XLA runtime rejections).
# Deliberately NOT BaseException: KeyboardInterrupt and SystemExit
# propagate out of the sweep immediately.
_LAUNCH_ERRORS = (ValueError, TypeError, AssertionError,
                  NotImplementedError, IndexError, RuntimeError)


def default_tune_candidates(backend: str = "jnp") -> list[dict]:
    """Backend-aware candidate set for :func:`tune_conv_blocks`.

    The gate-interpreter backends sweep the full c_unroll x m_block
    cross.  The fused backend drops ``c_unroll=8``: its win comes from
    the single-kernel emission rather than chain depth, wide formats
    are clamped to ``k=1`` anyway (``fused_chain_k``), and every extra
    chain depth is another multi-minute XLA compile in the sweep.
    """
    unrolls = (1, 2, 4) if backend == "pallas_fused" else (1, 2, 4, 8)
    return [{"c_unroll": u, "m_block": m}
            for u in unrolls for m in (8, 32, 128)]


def tune_conv_blocks(images, kernels, *, fmt: FPFormat,
                     backend: str = "jnp", interpret: bool = False,
                     candidates=None, iters: int = 2, **conv_kw):
    """Small sweep helper: time ``hobflops_conv2d`` over block-size /
    ``c_unroll`` candidates and return ``(best_blocks, results)``.

    ``candidates`` is an iterable of dicts with any of
    ``p_block/m_block/c_block/c_unroll`` set (missing keys fall back to
    the derived defaults); by default a c_unroll x m_block cross sweep.
    ``results`` maps the *resolved* (post-clamp) parameter tuple to
    seconds/call — candidates that clamp to the same launch config are
    timed once.  Only launch-relevant errors (``_LAUNCH_ERRORS``) mark
    a candidate as failed — interrupts re-raise immediately — and if
    every candidate fails the final ``RuntimeError`` names the last
    failing candidate dict and its error.
    """
    if candidates is None:
        candidates = default_tune_candidates(backend)
    if isinstance(kernels, ConvWeights):
        khh, kww, C, M = (kernels.kh, kernels.kw, kernels.cin,
                          kernels.cout)
    else:
        khh, kww, C, M = kernels.shape
    B, H, W, _ = images.shape
    # Resolve through the same clamping the launch will apply so
    # equivalent candidates dedupe — with the exact strided Ho*Wo patch
    # count, not the unstrided B*H*W (which could clamp differently).
    Ho, Wo = conv_out_hw(H, W, khh, kww, conv_kw.get("stride", 1),
                         conv_kw.get("padding", "SAME"))
    results: dict[tuple, float] = {}
    best, best_dt = None, float("inf")
    last_err, last_cand = None, None
    for cand in candidates:
        key = tuple(sorted(derive_blocks(B * Ho * Wo, khh * kww * C, M,
                                         **cand).items()))
        if key in results:
            continue
        run = lambda: jax.block_until_ready(hobflops_conv2d(
            images, kernels, fmt=fmt, backend=backend,
            interpret=interpret, **cand, **conv_kw))
        try:
            run()                                   # compile + warm
            t0 = time.perf_counter()
            for _ in range(iters):
                run()
            dt = (time.perf_counter() - t0) / iters
        except _LAUNCH_ERRORS as e:                 # unlaunchable combo
            last_err, last_cand = e, dict(cand)
            continue
        results[key] = dt
        if dt < best_dt:
            best, best_dt = dict(cand), dt
    if best is None:
        raise RuntimeError(
            "tune_conv_blocks: no candidate launched; last failing "
            f"candidate {last_cand!r} raised "
            f"{type(last_err).__name__}: {last_err}") from last_err
    return best, results
