"""Multi-layer CNN inference that stays in the HOBFLOPS bitslice domain.

The paper's throughput story (§3.4, Fig. 5) assumes IFM data remains in
bitslice format *between* layers.  :class:`HobflopsNetwork` realizes
that flow (DESIGN.md §8): activations are encoded to bit planes exactly
once at the network input, every interior layer boundary is a
plane-domain cast (``fpcore.build_cast``) + plane-domain im2col
(``ops.activation_patch_masks``) — pure bitwise/gather ops, no float32
materialization — and values are decoded exactly once at the output.

Weights are encoded to bit planes once at construction
(:class:`~repro.kernels.conv2d_bitslice.ops.ConvWeights`) and the
compiled MAC-chain / cast netlists are shared across layers with the
same format, so repeated inference calls pay zero re-encoding cost.

``run_roundtrip`` executes the same network through the per-layer
``hobflops_conv2d`` (decode to f32 / re-encode at every boundary) —
bit-exact to the resident path (``softfloat.fp_cast`` equals
encode∘decode; tests verify).  ``benchmarks/network.py`` measures the
resident speedup against the equivalent per-layer chains, with f32
kernels (the pre-PR caller cost) and with pre-encoded weights
(isolating the activation-residency saving).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import numpy as np

from repro.core.fpformat import RNE, FPFormat
from repro.kernels.conv2d_bitslice.ops import (ConvWeights,
                                               cast_activations, conv_core,
                                               conv_out_hw,
                                               decode_activations,
                                               encode_activations,
                                               encode_conv_weights,
                                               hobflops_conv2d)


@dataclasses.dataclass(frozen=True)
class LayerCfg:
    """Static per-layer configuration (hashable: rides in jit closures)."""
    stride: int = 1
    padding: str = "SAME"
    relu: bool = True
    extended: bool = False
    rounding: str = RNE


@dataclasses.dataclass
class ConvLayerSpec:
    """One conv layer of a :class:`HobflopsNetwork`.

    ``kernels`` is an f32 ``[kh, kw, cin, cout]`` array or a pre-encoded
    :class:`ConvWeights`; ``fmt`` is the layer's *operand* format (the
    accumulator runs at ``fmt.mult_out(extended)`` and is cast back down
    at the next layer's boundary).
    """
    kernels: object
    fmt: FPFormat
    stride: int = 1
    padding: str = "SAME"
    relu: bool = True
    extended: bool = False
    rounding: str = RNE

    def cfg(self) -> LayerCfg:
        return LayerCfg(self.stride, self.padding, self.relu,
                        self.extended, self.rounding)


def _run_resident(images, weights, *, cfgs, backend, interpret):
    act = encode_activations(images, weights[0].fmt, cfgs[0].rounding)
    for w, c in zip(weights, cfgs):
        # Layer boundary: round the previous accumulator format down to
        # this layer's operand format as a bitwise netlist (identity at
        # the entry layer).  No f32 anywhere between encode and decode.
        act = cast_activations(act, w.fmt, c.rounding)
        act = conv_core(act, w, stride=c.stride, padding=c.padding,
                        extended=c.extended, rounding=c.rounding,
                        relu=c.relu, backend=backend, interpret=interpret)
    return decode_activations(act)


def _run_roundtrip(images, weights, *, cfgs, backend, interpret):
    x = images
    for w, c in zip(weights, cfgs):
        x = hobflops_conv2d(x, w, fmt=w.fmt, stride=c.stride,
                            padding=c.padding, relu=c.relu,
                            extended=c.extended, rounding=c.rounding,
                            backend=backend, interpret=interpret)
    return x


class HobflopsNetwork:
    """A sequential stack of HOBFLOPS conv layers, bitslice-resident.

    >>> net = HobflopsNetwork([ConvLayerSpec(k1, fmt), ConvLayerSpec(k2, fmt)])
    >>> y = net(x)                  # one encode, one decode
    >>> y_ref = net.run_roundtrip(x)   # per-layer f32 boundaries (baseline)
    """

    def __init__(self, layers: Sequence[ConvLayerSpec],
                 backend: str = "jnp", interpret: bool = False):
        assert layers, "need at least one layer"
        self.weights: tuple[ConvWeights, ...] = tuple(
            spec.kernels if isinstance(spec.kernels, ConvWeights)
            else encode_conv_weights(np.asarray(spec.kernels, np.float32),
                                     spec.fmt, spec.rounding)
            for spec in layers)
        for spec, w in zip(layers, self.weights):
            assert w.fmt == spec.fmt, (w.fmt, spec.fmt)
        for prev, nxt in zip(self.weights, self.weights[1:]):
            assert prev.cout == nxt.cin, \
                f"layer chain mismatch: cout {prev.cout} -> cin {nxt.cin}"
        self.cfgs: tuple[LayerCfg, ...] = tuple(s.cfg() for s in layers)
        self.backend = backend
        self._resident = jax.jit(functools.partial(
            _run_resident, cfgs=self.cfgs, backend=backend,
            interpret=interpret))
        self._roundtrip = jax.jit(functools.partial(
            _run_roundtrip, cfgs=self.cfgs, backend=backend,
            interpret=interpret))

    def __call__(self, images):
        """f32 NHWC -> f32 NHWC through the bitslice-resident pipeline
        (single activation encode, single decode)."""
        return self._resident(images, self.weights)

    run_resident = __call__

    def run_roundtrip(self, images):
        """Same network through chained single-layer ``hobflops_conv2d``
        calls (f32 decode/re-encode at every layer boundary).
        Bit-exact to :meth:`run_resident`; exists as the equivalence
        oracle and the benchmark baseline."""
        return self._roundtrip(images, self.weights)

    def out_shape(self, in_shape) -> tuple[int, int, int, int]:
        """NHWC output shape for an NHWC input shape."""
        B, H, W, C = in_shape
        assert C == self.weights[0].cin, (C, self.weights[0].cin)
        for w, c in zip(self.weights, self.cfgs):
            H, W = conv_out_hw(H, W, w.kh, w.kw, c.stride, c.padding)
            C = w.cout
        return (B, H, W, C)

    def macs(self, in_shape) -> int:
        """Total multiply-accumulates for one forward pass."""
        B, H, W, _ = in_shape
        total = 0
        for w, c in zip(self.weights, self.cfgs):
            H, W = conv_out_hw(H, W, w.kh, w.kw, c.stride, c.padding)
            total += B * H * W * w.kh * w.kw * w.cin * w.cout
        return total
