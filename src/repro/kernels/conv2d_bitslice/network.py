"""Graph-structured CNN inference that stays in the HOBFLOPS bitslice
domain (DESIGN.md §8-§9).

The paper's throughput story (§3.4, Fig. 5) assumes IFM data remains in
bitslice format *between* layers, and its headline pitch is arbitrary
per-layer custom precision.  :class:`NetworkGraph` realizes both for
real topologies — residual blocks, pooled classifier heads, strided
downsamples — not just straight conv chains:

* Nodes are **named** and carry explicit input edges; kinds are
  ``conv``, ``maxpool2d``, ``avgpool2d``, ``add``, ``cast``, ``relu``
  (plus the implicit ``input``).  References are checked at insertion
  (nodes are declared before use, so the graph is a DAG by
  construction) and channel compatibility is validated when the graph
  is frozen by :meth:`NetworkGraph.output` — replacing the old runner's
  ad-hoc asserts with named-node error messages.
* Every node has an *operand format*: convs take a per-node
  ``precision`` (``fmt``), ``add``/``cast`` take a target format, pools
  and ``relu`` inherit.  Where a producer's format differs from a
  consumer's operand format the runner inserts a plane-domain
  ``build_cast`` automatically, so one network freely mixes e.g.
  hobflops8 early layers with hobflops11 late layers.
* The topo-order interpreter executes **entirely in the bitslice
  domain**: one ``encode_activations`` at the input node, one
  ``decode_activations`` at the output node, and in between only plane
  ops — the MAC kernel, ``build_cast``, ``build_max`` folds (maxpool),
  ``build_add`` trees + ``build_scale`` (avgpool, residual adds), and
  the one-ANDN-per-plane ReLU.  A test asserts the jaxpr holds exactly
  two ``bitcast_convert_type`` ops even for branched, strided graphs.

``run_roundtrip`` executes the same graph with **f32 edges**: every
node encodes its inputs, applies the word-parallel softfloat oracle
(``fp_max``/``fp_add``/``fp_scale``/``fp_relu``/``fp_cast``-via-encode),
and decodes.  Because ``encode`` is exact on decoded values and each
plane netlist is verified bit-exactly against its oracle, the two paths
are bit-identical — the per-layer f32-boundary oracle the tests and
benchmarks compare against.

:class:`HobflopsNetwork` survives as a thin, API-compatible wrapper
that lowers a ``Sequence[ConvLayerSpec]`` onto a linear graph.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import softfloat as sf
from repro.core.fpformat import RNE, FPFormat
from repro.kernels.conv2d_bitslice.ops import (ConvWeights, _conv_pad,
                                               _fold_pairwise,
                                               add_activations,
                                               avgpool2d_activations,
                                               cast_activations, conv_core,
                                               conv_out_hw,
                                               decode_activations,
                                               encode_activations,
                                               encode_conv_weights,
                                               hobflops_conv2d,
                                               maxpool2d_activations,
                                               neg_inf_code,
                                               relu_activations)

NODE_KINDS = ("input", "conv", "maxpool2d", "avgpool2d", "add", "cast",
              "relu")


@dataclasses.dataclass(frozen=True)
class GraphNode:
    """One node of a :class:`NetworkGraph` (hashable: the node tuple is
    a static jit argument, so topology and per-node formats are
    compile-time structure).  ``precision`` is the operand format for
    ``conv``, the target format for ``cast``/``add`` (None on ``add``
    means "first input's format"), and unused elsewhere.  ``blocks``
    holds conv launch-parameter overrides (p_block/m_block/c_block/
    c_unroll, e.g. a ``tune_conv_blocks`` winner) as a sorted item
    tuple; empty means the shape-derived defaults."""
    name: str
    kind: str
    inputs: tuple[str, ...] = ()
    precision: FPFormat | None = None
    stride: int = 1
    padding: str = "SAME"
    relu: bool = False
    extended: bool = False
    rounding: str = RNE
    window: tuple[int, int] = (2, 2)
    blocks: tuple = ()


class GraphValidationError(ValueError):
    """A topology/shape/format inconsistency, named after its node."""


def _format_plan(nodes: tuple[GraphNode, ...],
                 input_fmt: FPFormat) -> dict[str, FPFormat]:
    """Output format of every node.  Convs emit the accumulator format
    ``precision.mult_out(extended)``; casts/adds emit their target;
    pools and relu inherit their input's format."""
    fmts: dict[str, FPFormat] = {}
    for nd in nodes:
        if nd.kind == "input":
            fmts[nd.name] = input_fmt
        elif nd.kind == "conv":
            fmts[nd.name] = nd.precision.mult_out(nd.extended)
        elif nd.kind == "cast":
            fmts[nd.name] = nd.precision
        elif nd.kind == "add":
            fmts[nd.name] = nd.precision or fmts[nd.inputs[0]]
        else:  # maxpool2d / avgpool2d / relu
            fmts[nd.name] = fmts[nd.inputs[0]]
    return fmts


# ---------------------------------------------------------------------------
# Topo-order interpreters (module-level so jax.jit caches per graph)
# ---------------------------------------------------------------------------
def _exec_resident(images, weights, *, nodes, out_name, input_fmt,
                   backend, interpret):
    """Bitslice-resident execution: one encode, one decode, every edge
    a :class:`BitsliceActivation` in the plane domain."""
    fmts = _format_plan(nodes, input_fmt)
    acts = {}
    for nd in nodes:
        if nd.kind == "input":
            acts[nd.name] = encode_activations(images, input_fmt,
                                               nd.rounding)
            continue
        x = acts[nd.inputs[0]]
        if nd.kind == "conv":
            x = cast_activations(x, nd.precision, nd.rounding)
            out = conv_core(x, weights[nd.name], stride=nd.stride,
                            padding=nd.padding, extended=nd.extended,
                            rounding=nd.rounding, relu=nd.relu,
                            backend=backend, interpret=interpret,
                            **dict(nd.blocks))
        elif nd.kind == "cast":
            out = cast_activations(x, nd.precision, nd.rounding)
        elif nd.kind == "relu":
            out = relu_activations(x)
        elif nd.kind == "maxpool2d":
            out = maxpool2d_activations(x, nd.window, stride=nd.stride,
                                        padding=nd.padding)
        elif nd.kind == "avgpool2d":
            out = avgpool2d_activations(x, nd.window, stride=nd.stride,
                                        padding=nd.padding,
                                        rounding=nd.rounding)
        elif nd.kind == "add":
            out = add_activations(x, acts[nd.inputs[1]], fmts[nd.name],
                                  nd.rounding)
        else:  # pragma: no cover
            raise ValueError(nd.kind)
        acts[nd.name] = out
    return decode_activations(acts[out_name])


def _window_codes(codes, kh, kw, stride, pad_h, pad_w, fill):
    """NHWC code-word windows for the word-parallel pooling oracle —
    same geometry (low-half-first pad split, strided gather) as the
    plane-domain ``window_gather_planes``."""
    ph0, pw0 = pad_h // 2, pad_w // 2
    x = jnp.pad(codes, ((0, 0), (ph0, pad_h - ph0), (pw0, pad_w - pw0),
                        (0, 0)), constant_values=fill)
    Ho = (x.shape[1] - kh) // stride + 1
    Wo = (x.shape[2] - kw) // stride + 1
    return [x[:, i:i + (Ho - 1) * stride + 1:stride,
              j:j + (Wo - 1) * stride + 1:stride, :]
            for i in range(kh) for j in range(kw)]


def _oracle_pool(x, fmt, nd: GraphNode):
    """f32 -> f32 pooling through the word-parallel code oracle
    (``fp_max`` or ``fp_add``-tree + ``fp_scale``), bit-exact to the
    plane-domain netlist fold."""
    kh, kw = nd.window
    B, H, W, C = x.shape
    pad_h, pad_w = _conv_pad(H, W, kh, kw, nd.stride, nd.padding)
    codes = sf.encode_jnp(x, fmt)
    if nd.kind == "maxpool2d":
        wins = _window_codes(codes, kh, kw, nd.stride, pad_h, pad_w,
                             neg_inf_code(fmt))
        out = _fold_pairwise(wins, lambda a, b: sf.fp_max(a, b, fmt, jnp))
    else:
        wins = _window_codes(codes, kh, kw, nd.stride, pad_h, pad_w, 0)
        out = _fold_pairwise(
            wins, lambda a, b: sf.fp_add(a, b, fmt, nd.rounding, jnp))
        out = sf.fp_scale(out, (kh * kw).bit_length() - 1, fmt, jnp)
    return sf.decode_jnp(out, fmt)


def _exec_roundtrip(images, weights, *, nodes, out_name, input_fmt,
                    backend, interpret):
    """Per-layer f32-boundary oracle: every edge is float32; each node
    encodes, applies the word-parallel softfloat oracle, and decodes.
    Bit-exact to :func:`_exec_resident` (encode is exact on decoded
    values, and every plane netlist is oracle-verified)."""
    fmts = _format_plan(nodes, input_fmt)
    vals = {}
    for nd in nodes:
        if nd.kind == "input":
            # Quantize through the entry format exactly like the
            # resident path's single entry encode; every downstream
            # re-encode then operates on exactly-representable values,
            # which is what makes the two paths bit-identical.
            codes = sf.encode_jnp(jnp.asarray(images, jnp.float32),
                                  input_fmt, nd.rounding)
            vals[nd.name] = sf.decode_jnp(codes, input_fmt)
            continue
        x = vals[nd.inputs[0]]
        fmt_in = fmts[nd.inputs[0]]
        if nd.kind == "conv":
            out = hobflops_conv2d(x, weights[nd.name], fmt=nd.precision,
                                  stride=nd.stride, padding=nd.padding,
                                  relu=nd.relu, extended=nd.extended,
                                  rounding=nd.rounding, backend=backend,
                                  interpret=interpret, **dict(nd.blocks))
        elif nd.kind == "cast":
            codes = sf.encode_jnp(x, nd.precision, nd.rounding)
            out = sf.decode_jnp(codes, nd.precision)
        elif nd.kind == "relu":
            codes = sf.fp_relu(sf.encode_jnp(x, fmt_in), fmt_in, jnp)
            out = sf.decode_jnp(codes, fmt_in)
        elif nd.kind in ("maxpool2d", "avgpool2d"):
            out = _oracle_pool(x, fmt_in, nd)
        elif nd.kind == "add":
            tgt = fmts[nd.name]
            ca = sf.encode_jnp(x, tgt, nd.rounding)
            cb = sf.encode_jnp(vals[nd.inputs[1]], tgt, nd.rounding)
            out = sf.decode_jnp(sf.fp_add(ca, cb, tgt, nd.rounding, jnp),
                                tgt)
        else:  # pragma: no cover
            raise ValueError(nd.kind)
        vals[nd.name] = out
    return vals[out_name]


# ---------------------------------------------------------------------------
# The graph builder / validator / runner
# ---------------------------------------------------------------------------
class NetworkGraph:
    """A DAG of HOBFLOPS nodes, executed bitslice-resident.

    >>> g = NetworkGraph(fmt8)
    >>> c1 = g.conv("c1", g.input_name, k1, relu=True)
    >>> p1 = g.maxpool2d("p1", c1, window=2)
    >>> c2 = g.conv("c2", p1, k2, fmt=fmt11)       # mixed precision
    >>> g.output(g.add("res", c2, g.cast("skip", p1, fmt11.mult_out())))
    >>> y = g.run(x)                 # one encode, one decode
    >>> y_ref = g.run_roundtrip(x)   # f32-boundary oracle, bit-exact

    Node-builder methods return the node name so graphs compose as
    chains of calls.  ``output`` freezes the graph, validates channel
    compatibility, and compiles both runners.
    """

    def __init__(self, input_fmt: FPFormat, backend: str = "jnp",
                 interpret: bool = False, input_name: str = "input",
                 input_rounding: str = RNE):
        self.input_fmt = input_fmt
        self.input_name = input_name
        self.backend = backend
        self.interpret = interpret
        self._nodes: dict[str, GraphNode] = {
            input_name: GraphNode(input_name, "input", (), input_fmt,
                                  rounding=input_rounding)}
        self._weights: dict[str, ConvWeights] = {}
        # f32 kernels retained when conv() is given raw arrays, so
        # with_precision() can re-encode the same weights at another
        # format (pre-encoded ConvWeights carry only codes)
        self._kernels_f32: dict[str, np.ndarray] = {}
        self._out: str | None = None
        self._resident_fn = None
        self._roundtrip_fn = None

    # -- builders ----------------------------------------------------------
    def _insert(self, node: GraphNode) -> str:
        if self._out is not None:
            raise GraphValidationError(
                f"graph is frozen (output() was called); cannot add "
                f"node {node.name!r}")
        if node.name in self._nodes:
            raise GraphValidationError(f"duplicate node name {node.name!r}")
        for src in node.inputs:
            if src not in self._nodes:
                raise GraphValidationError(
                    f"node {node.name!r}: unknown input {src!r} "
                    f"(nodes must be declared before use)")
        self._nodes[node.name] = node
        return node.name

    def conv(self, name: str, src: str, kernels, fmt: FPFormat | None = None,
             *, stride: int = 1, padding: str = "SAME", relu: bool = False,
             extended: bool = False, rounding: str = RNE,
             blocks: dict | None = None) -> str:
        """Conv node: ``precision``/``fmt`` is the operand format (the
        graph input format by default); output carries the accumulator
        format ``fmt.mult_out(extended)``.  ``kernels`` is f32
        ``[kh, kw, cin, cout]`` or a pre-encoded :class:`ConvWeights`.
        ``blocks`` optionally pins launch parameters (p_block/m_block/
        c_block/c_unroll — e.g. a ``tune_conv_blocks`` winner) for this
        node's kernel launch; both runners thread them through, so a
        tuned serving graph actually executes its tuned configuration."""
        fmt = fmt or self.input_fmt
        if blocks:
            bad = set(blocks) - {"p_block", "m_block", "c_block",
                                 "c_unroll"}
            if bad:
                raise GraphValidationError(
                    f"conv {name!r}: unknown launch block keys {bad}")
        if isinstance(kernels, ConvWeights):
            w = kernels
            if w.fmt != fmt:
                raise GraphValidationError(
                    f"conv {name!r}: pre-encoded weights are {w.fmt}, "
                    f"node precision is {fmt}")
        else:
            kernels = np.asarray(kernels, np.float32)
            w = encode_conv_weights(kernels, fmt, rounding)
            self._kernels_f32[name] = kernels
        nm = self._insert(GraphNode(name, "conv", (src,), fmt,
                                    stride=stride, padding=padding,
                                    relu=relu, extended=extended,
                                    rounding=rounding,
                                    blocks=tuple(sorted(
                                        (blocks or {}).items()))))
        self._weights[name] = w
        return nm

    def maxpool2d(self, name: str, src: str, window=2, *,
                  stride: int | None = None,
                  padding: str = "VALID") -> str:
        kh, kw = (window, window) if isinstance(window, int) else window
        return self._insert(GraphNode(name, "maxpool2d", (src,),
                                      stride=stride or kh, padding=padding,
                                      window=(kh, kw)))

    def avgpool2d(self, name: str, src: str, window=2, *,
                  stride: int | None = None, padding: str = "VALID",
                  rounding: str = RNE) -> str:
        kh, kw = (window, window) if isinstance(window, int) else window
        if (kh * kw) & (kh * kw - 1):
            raise GraphValidationError(
                f"avgpool2d {name!r}: window area {kh}x{kw} is not a "
                f"power of two (the divider-free add-tree + "
                f"build_scale lowering needs one)")
        return self._insert(GraphNode(name, "avgpool2d", (src,),
                                      stride=stride or kh, padding=padding,
                                      rounding=rounding, window=(kh, kw)))

    def add(self, name: str, a: str, b: str, fmt: FPFormat | None = None,
            *, rounding: str = RNE) -> str:
        """Residual merge.  Branches are auto-cast to ``fmt`` (default:
        the first input's format) before the plane-domain add."""
        return self._insert(GraphNode(name, "add", (a, b), fmt,
                                      rounding=rounding))

    def cast(self, name: str, src: str, fmt: FPFormat, *,
             rounding: str = RNE) -> str:
        return self._insert(GraphNode(name, "cast", (src,), fmt,
                                      rounding=rounding))

    def relu(self, name: str, src: str) -> str:
        return self._insert(GraphNode(name, "relu", (src,)))

    # -- freeze + validate -------------------------------------------------
    def output(self, name: str) -> "NetworkGraph":
        """Mark ``name`` as the graph output, validate the whole graph
        (channel compatibility), prune nodes that do not feed the
        output, and compile the resident + roundtrip runners.  Returns
        self."""
        if name not in self._nodes:
            raise GraphValidationError(f"output(): unknown node {name!r}")
        self._validate_channels()
        self._out = name
        # Prune to the ancestor set of the output: dead branches are
        # neither traced nor shipped into the jitted call.
        live = {name}
        stack = [name]
        while stack:
            for src in self._nodes[stack.pop()].inputs:
                if src not in live:
                    live.add(src)
                    stack.append(src)
        nodes = tuple(nd for nd in self._nodes.values()
                      if nd.name in live)
        self._live_nodes = nodes
        self._live_weights = {k: w for k, w in self._weights.items()
                              if k in live}
        static = dict(nodes=nodes, out_name=name,
                      input_fmt=self.input_fmt, backend=self.backend,
                      interpret=self.interpret)
        self._resident_fn = jax.jit(
            functools.partial(_exec_resident, **static))
        self._roundtrip_fn = jax.jit(
            functools.partial(_exec_roundtrip, **static))
        return self

    def _validate_channels(self):
        """Channel-count propagation: convs fix the count, every other
        kind preserves it; mismatches raise with both node names."""
        ch: dict[str, int | None] = {}
        for nd in self._nodes.values():
            if nd.kind == "input":
                ch[nd.name] = None
            elif nd.kind == "conv":
                w = self._weights[nd.name]
                src_ch = ch[nd.inputs[0]]
                if src_ch is not None and src_ch != w.cin:
                    raise GraphValidationError(
                        f"conv {nd.name!r}: input {nd.inputs[0]!r} "
                        f"carries {src_ch} channels but the kernels "
                        f"expect cin={w.cin}")
                ch[nd.name] = w.cout
            elif nd.kind == "add":
                ca, cb = ch[nd.inputs[0]], ch[nd.inputs[1]]
                if ca is not None and cb is not None and ca != cb:
                    raise GraphValidationError(
                        f"add {nd.name!r}: inputs {nd.inputs[0]!r} "
                        f"({ca} ch) and {nd.inputs[1]!r} ({cb} ch) "
                        f"disagree")
                ch[nd.name] = ca if ca is not None else cb
            else:
                ch[nd.name] = ch[nd.inputs[0]]

    # -- shape / format plans ---------------------------------------------
    def format_plan(self) -> dict[str, FPFormat]:
        return _format_plan(tuple(self._nodes.values()), self.input_fmt)

    def shape_plan(self, in_shape) -> dict[str, tuple]:
        """NHWC shape of every node's output for a given input shape,
        with named-node errors replacing the old ad-hoc asserts."""
        shapes: dict[str, tuple] = {}
        for nd in self._nodes.values():
            if nd.kind == "input":
                shapes[nd.name] = tuple(in_shape)
                continue
            B, H, W, C = shapes[nd.inputs[0]]
            if nd.kind == "conv":
                w = self._weights[nd.name]
                if C != w.cin:
                    raise GraphValidationError(
                        f"conv {nd.name!r}: input has {C} channels, "
                        f"kernels expect cin={w.cin}")
                Ho, Wo = conv_out_hw(H, W, w.kh, w.kw, nd.stride,
                                     nd.padding)
                if Ho < 1 or Wo < 1:
                    raise GraphValidationError(
                        f"conv {nd.name!r}: kernel {w.kh}x{w.kw} "
                        f"(stride {nd.stride}, {nd.padding}) does not "
                        f"fit the {H}x{W} input")
                shapes[nd.name] = (B, Ho, Wo, w.cout)
            elif nd.kind in ("maxpool2d", "avgpool2d"):
                kh, kw = nd.window
                Ho, Wo = conv_out_hw(H, W, kh, kw, nd.stride, nd.padding)
                if Ho < 1 or Wo < 1:
                    raise GraphValidationError(
                        f"{nd.kind} {nd.name!r}: window {kh}x{kw} "
                        f"(stride {nd.stride}, {nd.padding}) does not "
                        f"fit the {H}x{W} input")
                shapes[nd.name] = (B, Ho, Wo, C)
            elif nd.kind == "add":
                other = shapes[nd.inputs[1]]
                if (B, H, W, C) != other:
                    raise GraphValidationError(
                        f"add {nd.name!r}: branch shapes "
                        f"{(B, H, W, C)} ({nd.inputs[0]!r}) and "
                        f"{other} ({nd.inputs[1]!r}) differ")
                shapes[nd.name] = (B, H, W, C)
            else:  # cast / relu
                shapes[nd.name] = (B, H, W, C)
        return shapes

    def out_shape(self, in_shape) -> tuple[int, int, int, int]:
        assert self._out is not None, "call output() first"
        return self.shape_plan(in_shape)[self._out]

    def with_precision(self, fmt: FPFormat, *,
                       input_fmt: FPFormat | None = None,
                       fmt_map: dict | None = None) -> "NetworkGraph":
        """A same-topology variant of this graph with every conv's
        operand precision replaced by ``fmt`` — the builder for a
        serving engine's precision-degradation ladder (the cheaper
        variant answers the same requests with the same shapes at
        lower cost).

        Format-bearing fields map through a derived table: each
        original conv operand format goes to ``fmt`` and its
        accumulator formats ``mult_out(False/True)`` go to the matching
        ``fmt.mult_out``; explicit ``cast``/``add`` targets and the
        graph input format follow the same table (so a uniform-
        precision graph stays uniform at the new precision, and casts
        that targeted an accumulator format keep targeting the
        accumulator).  ``fmt_map`` overrides/extends the table for
        mixed-precision graphs that need finer control.  Weights are
        re-encoded from the retained f32 kernels; a conv built from
        pre-encoded :class:`ConvWeights` cannot be re-encoded and
        raises.  The variant is frozen iff this graph is frozen (same
        output node).
        """
        mapping: dict[FPFormat, FPFormat] = {}
        for nd in self._nodes.values():
            if nd.kind == "conv":
                old = nd.precision
                mapping[old] = fmt
                for ext in (False, True):
                    mapping[old.mult_out(ext)] = fmt.mult_out(ext)
        mapping.update(fmt_map or {})
        inp = self._nodes[self.input_name]
        g = NetworkGraph(
            input_fmt or mapping.get(self.input_fmt, fmt),
            backend=self.backend, interpret=self.interpret,
            input_name=self.input_name, input_rounding=inp.rounding)
        for nd in self._nodes.values():
            if nd.kind == "input":
                continue
            if nd.kind == "conv":
                kernels = self._kernels_f32.get(nd.name)
                if kernels is None:
                    raise GraphValidationError(
                        f"with_precision: conv {nd.name!r} was built "
                        f"from pre-encoded ConvWeights; re-encoding at "
                        f"{fmt} needs the f32 kernels — pass raw "
                        f"arrays to conv() for graphs that degrade")
                g.conv(nd.name, nd.inputs[0], kernels,
                       mapping.get(nd.precision, fmt), stride=nd.stride,
                       padding=nd.padding, relu=nd.relu,
                       extended=nd.extended, rounding=nd.rounding,
                       blocks=dict(nd.blocks) or None)
            elif nd.kind == "maxpool2d":
                g.maxpool2d(nd.name, nd.inputs[0], nd.window,
                            stride=nd.stride, padding=nd.padding)
            elif nd.kind == "avgpool2d":
                g.avgpool2d(nd.name, nd.inputs[0], nd.window,
                            stride=nd.stride, padding=nd.padding,
                            rounding=nd.rounding)
            elif nd.kind == "add":
                g.add(nd.name, nd.inputs[0], nd.inputs[1],
                      mapping.get(nd.precision) if nd.precision
                      else None, rounding=nd.rounding)
            elif nd.kind == "cast":
                g.cast(nd.name, nd.inputs[0],
                       mapping.get(nd.precision, nd.precision),
                       rounding=nd.rounding)
            else:  # relu
                g.relu(nd.name, nd.inputs[0])
        if self._out is not None:
            g.output(self._out)
        return g

    def signature(self) -> str:
        """Stable hash of the graph's *compiled structure*: topology,
        per-node static config, input format, backend, and conv weight
        geometry + format — but NOT weight values, which are runtime
        arguments to the compiled runner.  Graphs with equal signatures
        compile to interchangeable runners; the serve-side
        compiled-runner cache keys on this (it recomputes per wave, so
        the digest is memoized once the graph is frozen).  On a frozen
        graph only the *live* (output-ancestor) nodes are hashed —
        pruned dead branches are not part of the compiled runner, so
        they must not perturb the signature."""
        if self._out is not None and getattr(self, "_sig", None):
            return self._sig
        parts = [repr((self.input_fmt, self.backend, self.interpret,
                       self._out))]
        nodes = self._live_nodes if self._out is not None \
            else tuple(self._nodes.values())
        for nd in nodes:
            parts.append(repr(dataclasses.astuple(nd)))
            w = self._weights.get(nd.name)
            if w is not None:
                parts.append(repr((w.kh, w.kw, w.cin, w.cout, w.fmt)))
        sig = hashlib.sha256("\n".join(parts).encode()).hexdigest()[:16]
        if self._out is not None:
            self._sig = sig
        return sig

    def summary(self, in_shape) -> str:
        """Per-node table (name, op, output format, output shape, MACs)
        for a concrete input shape — the serve engine's startup log and
        the examples' verbose output.  Nodes appear in insertion order;
        the trailing row totals the conv MACs of one forward pass."""
        shapes = self.shape_plan(in_shape)
        fmts = self.format_plan()

        def fstr(f: FPFormat) -> str:
            return f"e{f.w_e}f{f.w_f}/{f.nbits}b"

        rows = [("node", "op", "format", "out shape", "MACs")]
        total = 0
        for nd in self._nodes.values():
            macs = 0
            if nd.kind == "conv":
                w = self._weights[nd.name]
                B, Ho, Wo, _ = shapes[nd.name]
                macs = B * Ho * Wo * w.kh * w.kw * w.cin * w.cout
            total += macs
            rows.append((nd.name, nd.kind, fstr(fmts[nd.name]),
                         "x".join(str(d) for d in shapes[nd.name]),
                         f"{macs:,}" if macs else "-"))
        rows.append(("total", "", "", "", f"{total:,}"))
        widths = [max(len(r[i]) for r in rows) for i in range(5)]
        lines = []
        for i, r in enumerate(rows):
            lines.append("  ".join(c.ljust(w)
                                   for c, w in zip(r, widths)).rstrip())
            if i == 0:
                lines.append("-" * len(lines[0]))
        return "\n".join(lines)

    def resident_runner(self):
        """The compiled bitslice-resident entrypoint as a bare batched
        callable ``images [B,H,W,C] f32 -> [B,Ho,Wo,M] f32`` with the
        live weights closed over and no per-call host-side shape
        re-validation.  The wave-serving engine validates a batch
        bucket's shape once (``shape_plan``) when the bucket is first
        seen, then drives waves through this."""
        assert self._out is not None, "call output() first"
        fn, weights = self._resident_fn, self._live_weights
        return lambda images: fn(images, weights)

    def macs(self, in_shape) -> int:
        """Total conv multiply-accumulates for one forward pass."""
        shapes = self.shape_plan(in_shape)
        total = 0
        for nd in self._nodes.values():
            if nd.kind == "conv":
                w = self._weights[nd.name]
                B, Ho, Wo, _ = shapes[nd.name]
                total += B * Ho * Wo * w.kh * w.kw * w.cin * w.cout
        return total

    # -- execution ---------------------------------------------------------
    def run(self, images):
        """f32 NHWC -> f32 NHWC, bitslice-resident (single encode,
        single decode; every interior edge in the plane domain)."""
        assert self._out is not None, "call output() first"
        self.shape_plan(np.shape(images))      # host-side validation
        return self._resident_fn(images, self._live_weights)

    __call__ = run

    def run_roundtrip(self, images):
        """Same graph with f32 edges and word-parallel oracles at every
        node — the bit-exact per-layer baseline."""
        assert self._out is not None, "call output() first"
        self.shape_plan(np.shape(images))
        return self._roundtrip_fn(images, self._live_weights)


# ---------------------------------------------------------------------------
# Sequential API (kept compatible): a thin linear-graph wrapper
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class LayerCfg:
    """Static per-layer configuration (hashable: rides in jit closures)."""
    stride: int = 1
    padding: str = "SAME"
    relu: bool = True
    extended: bool = False
    rounding: str = RNE


@dataclasses.dataclass
class ConvLayerSpec:
    """One conv layer of a :class:`HobflopsNetwork`.

    ``kernels`` is an f32 ``[kh, kw, cin, cout]`` array or a pre-encoded
    :class:`ConvWeights`; ``fmt`` is the layer's *operand* format (the
    accumulator runs at ``fmt.mult_out(extended)`` and is cast back down
    at the next layer's boundary).
    """
    kernels: object
    fmt: FPFormat
    stride: int = 1
    padding: str = "SAME"
    relu: bool = True
    extended: bool = False
    rounding: str = RNE

    def cfg(self) -> LayerCfg:
        return LayerCfg(self.stride, self.padding, self.relu,
                        self.extended, self.rounding)


class HobflopsNetwork:
    """A sequential stack of HOBFLOPS conv layers, bitslice-resident.

    Now a thin wrapper that lowers the layer list onto a linear
    :class:`NetworkGraph` (nodes ``conv0`` .. ``convN-1``) — same
    public API as before, same one-encode/one-decode execution.

    >>> net = HobflopsNetwork([ConvLayerSpec(k1, fmt), ConvLayerSpec(k2, fmt)])
    >>> y = net(x)                  # one encode, one decode
    >>> y_ref = net.run_roundtrip(x)   # per-layer f32 boundaries (baseline)
    """

    def __init__(self, layers: Sequence[ConvLayerSpec],
                 backend: str = "jnp", interpret: bool = False):
        assert layers, "need at least one layer"
        g = NetworkGraph(layers[0].fmt, backend=backend,
                         interpret=interpret,
                         input_rounding=layers[0].rounding)
        src = g.input_name
        for i, spec in enumerate(layers):
            src = g.conv(f"conv{i}", src, spec.kernels, spec.fmt,
                         stride=spec.stride, padding=spec.padding,
                         relu=spec.relu, extended=spec.extended,
                         rounding=spec.rounding)
        g.output(src)
        self.graph = g
        self._names = tuple(f"conv{i}" for i in range(len(layers)))
        self.weights: tuple[ConvWeights, ...] = tuple(
            g._weights[n] for n in self._names)
        self.cfgs: tuple[LayerCfg, ...] = tuple(s.cfg() for s in layers)
        self.backend = backend

    def _wdict(self, weights):
        return dict(zip(self._names, weights))

    def _resident(self, images, weights):
        return self.graph._resident_fn(images, self._wdict(weights))

    def _roundtrip(self, images, weights):
        return self.graph._roundtrip_fn(images, self._wdict(weights))

    def __call__(self, images):
        """f32 NHWC -> f32 NHWC through the bitslice-resident pipeline
        (single activation encode, single decode)."""
        return self._resident(images, self.weights)

    run_resident = __call__

    def run_roundtrip(self, images):
        """Same network through per-layer f32 boundaries (the oracle
        baseline).  Bit-exact to :meth:`run_resident`."""
        return self._roundtrip(images, self.weights)

    def out_shape(self, in_shape) -> tuple[int, int, int, int]:
        """NHWC output shape for an NHWC input shape."""
        return self.graph.out_shape(in_shape)

    def macs(self, in_shape) -> int:
        """Total multiply-accumulates for one forward pass."""
        return self.graph.macs(in_shape)
