"""Pure-jnp/numpy oracle for the bitslice MAC (HOBFLOPS GEMM).

Semantics: a HOBFLOPS inner product with sequential accumulation in
channel order, exactly as the paper's convolution performs it::

    O[p, m] = fold_c  add(mul(I[p, c], W[c, m]), acc)      # acc0 = +0

with the multiply rounding into the accumulator format
``fmt.mult_out(extended)`` and the add performed at that format.
Operates on integer code words (see repro.core.fpformat).
"""
from __future__ import annotations

import numpy as np

from repro.core import softfloat as sf
from repro.core.fpformat import RNE, FPFormat


def hobflops_matmul_ref(i_codes, w_codes, fmt: FPFormat,
                        extended: bool = False, rounding: str = RNE,
                        xp=np):
    """i_codes: [P, C] int, w_codes: [C, M] int -> [P, M] int codes."""
    fmt_out = fmt.mult_out(extended)
    i_codes = xp.asarray(i_codes)
    w_codes = xp.asarray(w_codes)
    P, C = i_codes.shape
    C2, M = w_codes.shape
    assert C == C2
    acc = xp.zeros((P, M), dtype=xp.int64 if xp is np else xp.int32)
    for c in range(C):
        x = xp.broadcast_to(i_codes[:, c][:, None], (P, M))
        y = xp.broadcast_to(w_codes[c][None, :], (P, M))
        prod = sf.fp_mul(x, y, fmt, fmt_out, rounding, xp)
        acc = sf.fp_add(prod, acc, fmt_out, rounding, xp)
    return acc


def hobflops_matmul_f64(i_vals, w_vals, fmt: FPFormat,
                        extended: bool = False,
                        rounding: str = RNE) -> np.ndarray:
    """Float-in/float-out convenience oracle (encodes, MACs, decodes)."""
    fmt_out = fmt.mult_out(extended)
    ic = sf.encode(np.asarray(i_vals, np.float64), fmt, rounding)
    wc = sf.encode(np.asarray(w_vals, np.float64), fmt, rounding)
    out = hobflops_matmul_ref(ic, wc, fmt, extended, rounding)
    return sf.decode(out, fmt_out)
