"""Pallas TPU kernel for the bitslice-parallel HOBFLOPS MAC (GEMM form).

TPU adaptation of the paper's CNN convolution (Fig. 5):

* The paper's SIMD register (128-512 bits) becomes a VMEM-resident tile
  of int32 lane words: every gate of the synthesized MAC netlist executes
  as one VPU elementwise op over a [P_blk, M_words] tile — an effective
  bitslice width of ``P_blk * M_words * 32`` lanes per instruction.
* Weights are bitsliced along the M (output-channel) axis — the paper's
  "tile the M kernels by LANES"; IFM bits are broadcast to all lanes as
  0/-1 masks — the paper's "broadcast the IFM channel across kernels".
* The reduction over input channels C runs as the innermost *grid*
  dimension with output-block revisiting, so the OFM accumulator planes
  stay resident in VMEM while C streams through (HBM->VMEM once).

Layouts:
    i_masks : [P, C, NIN]  int32, each element 0 or -1 (bit broadcast)
    w_planes: [C, NIN, Mw] int32, bit b of weight (c, 32*w+j) in bit j
              of w_planes[c, b, w]
    out     : [NOUT, P, Mw] int32 OFM bit planes
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.codegen import make_jax_fn
from repro.core.fpcore import build_mac
from repro.core.fpformat import RNE, FPFormat
from repro.core.opt import CELL_LIBS, tech_map


@functools.lru_cache(maxsize=None)
def mac_netlist_fn(fmt: FPFormat, extended: bool, rounding: str):
    """TPU-mapped MAC netlist as a traceable planes->planes function."""
    g = build_mac(fmt, extended, rounding)
    mapped = tech_map(g, CELL_LIBS["tpu_vpu"]())
    return make_jax_fn(mapped), mapped


def _mac_kernel(i_ref, w_ref, o_ref, *, c_block: int, nin: int, nout: int,
                fmt: FPFormat, extended: bool, rounding: str):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        # +0.0 in FloPoCo encoding is the all-zero code word.
        o_ref[...] = jnp.zeros_like(o_ref)

    fn, _ = mac_netlist_fn(fmt, extended, rounding)
    acc_shape = o_ref.shape[1:]  # [P_blk, Mt]

    def step(c, acc):
        xw = w_ref[c]                       # [NIN, Mt] weight planes
        yb = i_ref[:, c, :]                 # [P_blk, NIN] ifm masks
        x = xw[:, None, :]                  # [NIN, 1, Mt]
        y = jnp.transpose(yb, (1, 0))[:, :, None]   # [NIN, P_blk, 1]
        out = fn(x=x, y=y, acc=acc)["out"]
        return jnp.broadcast_to(out, (nout,) + acc_shape)

    acc = jax.lax.fori_loop(0, c_block, step, o_ref[...])
    o_ref[...] = acc


def bitslice_mac_pallas(i_masks, w_planes, *, fmt: FPFormat,
                        extended: bool = False, rounding: str = RNE,
                        p_block: int = 8, m_block: int = 128,
                        c_block: int = 64, interpret: bool = False):
    """Launch the bitslice MAC kernel.

    i_masks: [P, C, NIN] int32 in {0, -1}; w_planes: [C, NIN, Mw] int32.
    Returns OFM planes [NOUT, P, Mw] int32.  P % p_block == 0,
    Mw % m_block == 0, C % c_block == 0 (pad with +0 codes upstream —
    zero-padding is the identity for the HOBFLOPS MAC).
    """
    P, C, nin = i_masks.shape
    C2, nin2, Mw = w_planes.shape
    assert (C, nin) == (C2, nin2), (i_masks.shape, w_planes.shape)
    assert nin == fmt.nbits
    nout = fmt.mult_out(extended).nbits
    p_block = min(p_block, P)
    m_block = min(m_block, Mw)
    c_block = min(c_block, C)
    assert P % p_block == 0 and Mw % m_block == 0 and C % c_block == 0

    grid = (P // p_block, Mw // m_block, C // c_block)
    kernel = functools.partial(_mac_kernel, c_block=c_block, nin=nin,
                               nout=nout, fmt=fmt, extended=extended,
                               rounding=rounding)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((p_block, c_block, nin),
                         lambda pi, mi, ci: (pi, ci, 0)),
            pl.BlockSpec((c_block, nin, m_block),
                         lambda pi, mi, ci: (ci, 0, mi)),
        ],
        out_specs=pl.BlockSpec((nout, p_block, m_block),
                               lambda pi, mi, ci: (0, pi, mi)),
        out_shape=jax.ShapeDtypeStruct((nout, P, Mw), jnp.int32),
        interpret=interpret,
    )(i_masks, w_planes)
