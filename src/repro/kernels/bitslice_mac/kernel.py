"""Pallas TPU kernel for the bitslice-parallel HOBFLOPS MAC (GEMM form).

TPU adaptation of the paper's CNN convolution (Fig. 5):

* The paper's SIMD register (128-512 bits) becomes a VMEM-resident tile
  of int32 lane words: every gate of the synthesized MAC netlist executes
  as one VPU elementwise op over a [P_blk, M_words] tile — an effective
  bitslice width of ``P_blk * M_words * 32`` lanes per instruction.
* Weights are bitsliced along the M (output-channel) axis — the paper's
  "tile the M kernels by LANES"; IFM bits are broadcast to all lanes as
  0/-1 masks — the paper's "broadcast the IFM channel across kernels".
* The reduction over input channels C runs as the innermost *grid*
  dimension with output-block revisiting, so the OFM accumulator planes
  stay resident in VMEM while C streams through (HBM->VMEM once).
* Channels advance ``c_unroll`` at a time through a fused K-step MAC
  chain netlist (``build_mac_chain``): the per-step canonical
  pack/unpack is elided inside the chain and the ``fori_loop`` trip
  count drops by ``c_unroll`` — fewer gates *and* fewer loop steps per
  accumulated channel (DESIGN.md §3, §5).

Layouts:
    i_masks : [P, C, NIN]  int32, each element 0 or -1 (bit broadcast)
    w_planes: [C, NIN, Mw] int32, bit b of weight (c, 32*w+j) in bit j
              of w_planes[c, b, w]
    out     : [NOUT, P, Mw] int32 OFM bit planes
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.codegen import make_jax_fn
from repro.core.fpcore import (build_add, build_cast, build_mac_chain,
                               build_max, build_scale)
from repro.core.fpformat import RNE, FPFormat
from repro.core.opt import optimize_mapped


def _optimized_fn(graph, lib: str):
    """Shared plumbing: map a freshly built netlist into ``lib`` cells,
    run the post-mapping optimization passes (constant propagation,
    remap iteration, dead-node sweep), and wrap it as a traceable fn.
    Every ``*_netlist_fn`` below caches through this, so each
    (builder, format, options) combination pays graph construction,
    mapping, and register allocation exactly once per process."""
    mapped = optimize_mapped(graph, lib)
    return make_jax_fn(mapped), mapped


@functools.lru_cache(maxsize=None)
def mac_chain_netlist_fn(fmt: FPFormat, k: int, extended: bool,
                         rounding: str, lib: str = "tpu_vpu"):
    """Optimized ``lib``-mapped K-step MAC chain as a traceable fn.

    The chain is bit-exact to ``k`` sequential MAC steps; the mapped
    netlist additionally goes through the post-mapping optimization
    passes (constant propagation, remap iteration, dead-node sweep)."""
    return _optimized_fn(build_mac_chain(fmt, k, extended, rounding), lib)


@functools.lru_cache(maxsize=None)
def cast_netlist_fn(fmt_in: FPFormat, fmt_out: FPFormat, rounding: str,
                    lib: str = "tpu_vpu"):
    """Optimized ``lib``-mapped fmt_in -> fmt_out cast as a traceable fn.

    The inter-layer boundary op of the bitslice-resident pipeline
    (DESIGN.md §8): applied once per plane array between layers, it
    replaces the whole unpack -> decode -> f32 -> encode -> repack
    round-trip with a few dozen bitwise ops."""
    return _optimized_fn(build_cast(fmt_in, fmt_out, rounding), lib)


@functools.lru_cache(maxsize=None)
def add_netlist_fn(fmt: FPFormat, rounding: str = RNE,
                   lib: str = "tpu_vpu"):
    """Optimized elementwise FP adder (``build_add``) as a traceable fn
    — the residual-merge / avgpool-tree op of the graph runner
    (DESIGN.md §9), applied plane-wise over two activation arrays."""
    return _optimized_fn(build_add(fmt, rounding), lib)


@functools.lru_cache(maxsize=None)
def max_netlist_fn(fmt: FPFormat, lib: str = "tpu_vpu"):
    """Optimized elementwise FP max (``build_max``) as a traceable fn —
    the plane-domain maxpool reduction (DESIGN.md §9)."""
    return _optimized_fn(build_max(fmt), lib)


@functools.lru_cache(maxsize=None)
def scale_netlist_fn(fmt: FPFormat, k: int, lib: str = "tpu_vpu"):
    """Optimized multiply-by-2**-k (``build_scale``) as a traceable fn —
    the divider-free avgpool tail (DESIGN.md §9)."""
    return _optimized_fn(build_scale(fmt, k), lib)


def _chain_kwargs(xw, yb, c_unroll: int):
    """Per-step chain operands from [c_unroll, NIN, Mt] weight planes and
    [P_blk, c_unroll, NIN] ifm masks, shaped to broadcast to
    [NIN, P_blk, Mt] inside the netlist."""
    kwargs = {}
    for j in range(c_unroll):
        kwargs[f"x{j}"] = xw[j][:, None, :]                       # [NIN,1,Mt]
        kwargs[f"y{j}"] = jnp.transpose(yb[:, j, :], (1, 0))[:, :, None]
    return kwargs                                                 # [NIN,P,1]


def _mac_kernel(i_ref, w_ref, o_ref, *, c_block: int, c_unroll: int,
                nin: int, nout: int, fmt: FPFormat, extended: bool,
                rounding: str):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        # +0.0 in FloPoCo encoding is the all-zero code word.
        o_ref[...] = jnp.zeros_like(o_ref)

    fn, _ = mac_chain_netlist_fn(fmt, c_unroll, extended, rounding)
    acc_shape = o_ref.shape          # (NOUT, P_blk, Mt): explicit carry shape
    assert acc_shape[0] == nout, (acc_shape, nout)
    assert c_block % c_unroll == 0, (c_block, c_unroll)

    def step(s, acc):
        base = s * c_unroll
        xw = w_ref[pl.ds(base, c_unroll)]        # [c_unroll, NIN, Mt]
        yb = i_ref[:, pl.ds(base, c_unroll), :]  # [P_blk, c_unroll, NIN]
        out = fn(acc=acc, **_chain_kwargs(xw, yb, c_unroll))["out"]
        # Every output plane depends on the acc input, but planes that
        # collapse to a constant/broadcast still need the explicit
        # expansion for the fori_loop carry to keep a fixed shape.
        assert out.shape[0] == nout, (out.shape, nout)
        return jnp.broadcast_to(out, acc_shape)

    acc = jax.lax.fori_loop(0, c_block // c_unroll, step, o_ref[...])
    o_ref[...] = acc


def bitslice_mac_pallas(i_masks, w_planes, *, fmt: FPFormat,
                        extended: bool = False, rounding: str = RNE,
                        p_block: int = 8, m_block: int = 128,
                        c_block: int = 64, c_unroll: int = 4,
                        interpret: bool = False):
    """Launch the bitslice MAC kernel.

    i_masks: [P, C, NIN] int32 in {0, -1}; w_planes: [C, NIN, Mw] int32.
    Returns OFM planes [NOUT, P, Mw] int32.  P % p_block == 0,
    Mw % m_block == 0, C % c_block == 0 (pad with +0 codes upstream —
    zero-padding is the identity for the HOBFLOPS MAC), and
    c_block % c_unroll == 0 (clamped down when it does not divide).
    """
    P, C, nin = i_masks.shape
    C2, nin2, Mw = w_planes.shape
    assert (C, nin) == (C2, nin2), (i_masks.shape, w_planes.shape)
    assert nin == fmt.nbits
    nout = fmt.mult_out(extended).nbits
    p_block = min(p_block, P)
    m_block = min(m_block, Mw)
    c_block = min(c_block, C)
    assert P % p_block == 0 and Mw % m_block == 0 and C % c_block == 0
    c_unroll = max(1, min(c_unroll, c_block))
    while c_block % c_unroll:
        c_unroll -= 1

    grid = (P // p_block, Mw // m_block, C // c_block)
    kernel = functools.partial(_mac_kernel, c_block=c_block,
                               c_unroll=c_unroll, nin=nin, nout=nout,
                               fmt=fmt, extended=extended,
                               rounding=rounding)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((p_block, c_block, nin),
                         lambda pi, mi, ci: (pi, ci, 0)),
            pl.BlockSpec((c_block, nin, m_block),
                         lambda pi, mi, ci: (ci, 0, mi)),
        ],
        out_specs=pl.BlockSpec((nout, p_block, m_block),
                               lambda pi, mi, ci: (0, pi, mi)),
        out_shape=jax.ShapeDtypeStruct((nout, P, Mw), jnp.int32),
        interpret=interpret,
    )(i_masks, w_planes)
