"""Public jit'd API for the HOBFLOPS bitslice MAC.

``hobflops_matmul``: float32 in / float32 out GEMM whose arithmetic is
custom-precision HOBFLOPS FP executed bitslice-parallel.  Two backends:

* ``backend="pallas"``       — the TPU kernel (``interpret=True`` on
                               CPU); the netlist is traced per grid
                               step by the gate interpreter.
* ``backend="jnp"``          — the same synthesized netlist traced as
                               plain XLA elementwise ops over full
                               arrays; used for CPU benchmarking and as
                               a portability fallback.
* ``backend="pallas_fused"`` — the fused compiler backend
                               (``repro.core.pallas_backend``,
                               DESIGN.md §12): the whole MAC chain
                               lowered to a single-``pallas_call``
                               register-file kernel with the
                               fusion-shaped bus assembly.

All produce bit-identical results; tests cross-check them and the
pure softfloat oracle in ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import softfloat as sf
from repro.core.bitslice import pack_planes, unpack_planes
from repro.core.fpformat import RNE, FPFormat
from repro.core.pallas_backend import fused_mac_pallas

from .kernel import bitslice_mac_pallas, mac_chain_netlist_fn

LANE = 32


def _pad_to(x, mult, axis):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def encode_input_masks(i_f32, fmt: FPFormat, rounding: str = RNE,
                       p_block: int = 8, c_block: int = 64):
    """float32 [P,C] -> i_masks [P',C',NIN] int32 in {0,-1} (bit
    broadcast masks), P/C zero-padded to the block multiples."""
    ic = sf.encode_jnp(i_f32, fmt, rounding)        # [P, C] int32
    ic = _pad_to(_pad_to(ic, p_block, 0), c_block, 1)
    bits = (ic[..., None] >> jnp.arange(fmt.nbits, dtype=jnp.int32)) & 1
    return -bits.astype(jnp.int32)                   # 0 / -1 masks


def encode_weight_planes(w_f32, fmt: FPFormat, rounding: str = RNE,
                         c_block: int = 1, m_block: int = 1):
    """float32 [C,M] -> w_planes [C',NIN,Mw] int32 bit planes (M packed
    along int32 lanes).  Static inference weights should be encoded
    once through this and passed to ``hobflops_matmul(w_planes=...)`` /
    ``conv2d_bitslice.encode_conv_weights`` instead of re-encoding f32
    kernels on every call.  Defaults carry minimal padding (M to the
    next lane word only) so one encoding serves any launch block
    configuration; launch-time padding happens at the call site."""
    wc = sf.encode_jnp(w_f32, fmt, rounding)        # [C, M] int32
    wc = _pad_to(_pad_to(wc, c_block, 0), m_block * LANE, 1)
    return jnp.moveaxis(pack_planes(wc, fmt.nbits), 0, 1)  # [C, NIN, Mw]


def encode_inputs(i_f32, w_f32, fmt: FPFormat, rounding: str = RNE,
                  p_block: int = 8, m_block: int = 128, c_block: int = 64):
    """float32 [P,C] x [C,M] -> (i_masks [P,C,NIN], w_planes [C,NIN,Mw]),
    both padded out to the given launch blocks."""
    return (encode_input_masks(i_f32, fmt, rounding, p_block, c_block),
            encode_weight_planes(w_f32, fmt, rounding, c_block, m_block))


@functools.partial(jax.jit, static_argnames=(
    "fmt", "extended", "rounding", "backend", "interpret", "cout",
    "p_block", "m_block", "c_block", "c_unroll"))
def hobflops_matmul(i_f32, w_f32=None, *, fmt: FPFormat,
                    w_planes=None, cout: int | None = None,
                    extended: bool = False,
                    rounding: str = RNE, backend: str = "pallas",
                    interpret: bool = False, p_block: int = 8,
                    m_block: int = 128, c_block: int = 64,
                    c_unroll: int = 4):
    """GEMM [P,C] @ [C,M] -> [P,M] float32, in HOBFLOPS arithmetic.

    Weights are given either as float32 ``w_f32`` [C,M] (encoded to bit
    planes on every call) or pre-encoded ``w_planes`` [C,NIN,Mw] from
    :func:`encode_weight_planes` (``cout`` recovers M when it is not a
    full lane-word multiple).  Inference-time callers should pre-encode.
    """
    P, C = i_f32.shape
    if w_planes is None:
        C2, M = w_f32.shape
        assert C == C2
    else:
        assert w_f32 is None, "pass either w_f32 or w_planes, not both"
        C2, nin, Mw = w_planes.shape
        assert C == C2 and nin == fmt.nbits, (w_planes.shape, fmt)
        M = cout if cout is not None else Mw * LANE
        assert M <= Mw * LANE
    # Clamp blocks to the problem so padding never exceeds one block.
    p_block = max(1, min(p_block, P))
    c_block = max(1, min(c_block, C))
    m_block = max(1, min(m_block, -(-M // LANE)))
    i_masks = encode_input_masks(i_f32, fmt, rounding, p_block, c_block)
    if w_planes is None:
        w_planes = encode_weight_planes(w_f32, fmt, rounding, c_block,
                                        m_block)
    else:
        w_planes = _pad_to(_pad_to(w_planes, c_block, 0), m_block, 2)
    if backend == "pallas":
        out = bitslice_mac_pallas(
            i_masks, w_planes, fmt=fmt, extended=extended,
            rounding=rounding, p_block=p_block, m_block=m_block,
            c_block=c_block, c_unroll=c_unroll, interpret=interpret)
    elif backend == "pallas_fused":
        out = fused_mac_pallas(
            i_masks, w_planes, fmt=fmt, extended=extended,
            rounding=rounding, p_block=p_block, m_block=m_block,
            c_block=c_block, c_unroll=c_unroll, interpret=interpret)
    elif backend == "jnp":
        out = _bitslice_mac_jnp(i_masks, w_planes, fmt=fmt,
                                extended=extended, rounding=rounding,
                                c_unroll=c_unroll)
    else:
        raise ValueError(backend)
    fmt_out = fmt.mult_out(extended)
    codes = unpack_planes(out)                      # [P', Mw*32]
    vals = sf.decode_jnp(codes, fmt_out)
    return vals[:P, :M]


def _bitslice_mac_jnp(i_masks, w_planes, *, fmt: FPFormat, extended: bool,
                      rounding: str, c_unroll: int = 4):
    """Chain netlist over full arrays with a scan over C/c_unroll steps
    (pure XLA path).  C is padded to a multiple of ``c_unroll`` with +0
    codes — the all-zero planes — which are the MAC identity."""
    P, C, nin = i_masks.shape
    _, _, Mw = w_planes.shape
    nout = fmt.mult_out(extended).nbits
    ku = max(1, min(c_unroll, C))
    pad = (-C) % ku
    if pad:
        i_masks = jnp.pad(i_masks, ((0, 0), (0, pad), (0, 0)))
        w_planes = jnp.pad(w_planes, ((0, pad), (0, 0), (0, 0)))
        C += pad
    fn, _ = mac_chain_netlist_fn(fmt, ku, extended, rounding)
    acc0 = jnp.zeros((nout, P, Mw), jnp.int32)
    xs = (jnp.moveaxis(i_masks, 1, 0).reshape(C // ku, ku, P, nin),
          w_planes.reshape(C // ku, ku, nin, Mw))

    def step(acc, xw):
        ib, wp = xw                        # [ku, P, NIN], [ku, NIN, Mw]
        kwargs = {}
        for j in range(ku):
            kwargs[f"x{j}"] = wp[j][:, None, :]                 # [NIN,1,Mw]
            kwargs[f"y{j}"] = jnp.transpose(ib[j], (1, 0))[:, :, None]
        out = fn(acc=acc, **kwargs)["out"]
        return jnp.broadcast_to(out, acc.shape), None

    acc, _ = jax.lax.scan(step, acc0, xs)
    return acc


def hobflops_quantize(x_f32, fmt: FPFormat, rounding: str = RNE):
    """Round-trip float32 through the HOBFLOPS format (fake-quant)."""
    return sf.decode_jnp(sf.encode_jnp(x_f32, fmt, rounding), fmt)
