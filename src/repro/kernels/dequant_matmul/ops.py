"""Public API for the fused bitplane-dequant matmul."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.fpformat import StorageFormat
from repro.quant.storage import LANE, QuantizedTensor, quantize

from .kernel import dequant_matmul_pallas
from .ref import dequant_matmul_ref


def pack_weights(w, sfmt: StorageFormat) -> QuantizedTensor:
    """[K, N] float weights -> bitplane QuantizedTensor with the 2-D
    [nbits, K, N//32] layout the kernel streams (N % 32 == 0)."""
    K, N = w.shape
    assert N % LANE == 0, f"N={N} must be a multiple of {LANE}"
    qt = quantize(w, sfmt, layout="bitplane")
    data = qt.data.reshape(qt.data.shape[0], K, N // LANE)
    return QuantizedTensor(data=data, scale=qt.scale, sfmt=sfmt,
                           layout="bitplane2d", shape=(K, N))


@functools.partial(jax.jit, static_argnames=("backend", "interpret",
                                             "bm", "bn", "bk"))
def dequant_matmul(x, qt: QuantizedTensor, *, backend: str = "pallas",
                   interpret: bool = False, bm: int = 128, bn: int = 256,
                   bk: int = 512):
    """x [M, K] @ dequant(qt [K, N]) -> [M, N] f32."""
    K, N = qt.shape
    if backend == "pallas":
        return dequant_matmul_pallas(x, qt.data, qt.scale, qt.sfmt,
                                     N=N, bm=bm, bn=bn, bk=bk,
                                     interpret=interpret)
    assert backend == "jnp"
    return dequant_matmul_ref(x, qt.data, qt.scale, qt.sfmt, N)
