"""Pallas TPU kernel: fused bitplane-dequant + MXU matmul.

The serving-side of the paper's idea on TPU: weights live in HBM as
HOBFLOPS bitplane codes (exactly nbits bits per weight), and each
(K_blk, N_blk) weight tile is reassembled and decoded to bf16 *in VMEM*
right before the MXU consumes it — HBM weight traffic shrinks by
16/nbits vs bf16 with no persistent dequantized copy anywhere.

Tiling: grid (M/bm, N/bn, K/bk), K innermost with output revisiting so
the f32 accumulator tile stays in VMEM.  The plane tile is
[nbits, bk, bn//32] int32; unpack is `nbits` shift-ands + a shift-or
reassembly (VPU), then an exponent/mantissa bit-assembly to f32 via
bitcast — all fusable elementwise ops on the [bk, bn] tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.fpformat import StorageFormat

LANE = 32


def _decode_tile(words, sfmt: StorageFormat, scale):
    """[nbits, bk, bn//32] int32 planes -> [bk, bn] f32 weights."""
    nbits = words.shape[0]
    bk, bw = words.shape[1], words.shape[2]
    shifts = jax.lax.iota(jnp.int32, LANE)
    # reassemble integer codes: bit b of lane j comes from plane word
    codes = jnp.zeros((bk, bw, LANE), jnp.int32)
    for b in range(nbits):
        bits = (words[b][:, :, None] >> shifts) & 1
        codes = codes | (bits << b)
    codes = codes.reshape(bk, bw * LANE)
    # decode StorageFormat -> f32 (no subnormals; code 0 == +0)
    frac = codes & ((1 << sfmt.w_f) - 1)
    exp = (codes >> sfmt.w_f) & ((1 << sfmt.w_e) - 1)
    sign = (codes >> (sfmt.w_e + sfmt.w_f)) & 1
    e8 = exp - sfmt.bias + 127
    bits32 = (sign << 31) | (e8 << 23) | (frac << (23 - sfmt.w_f))
    val = jax.lax.bitcast_convert_type(bits32.astype(jnp.int32),
                                       jnp.float32)
    val = jnp.where(codes == 0, 0.0, val)
    return val * scale


def _dq_matmul_kernel(x_ref, w_ref, scale_ref, o_ref, *, sfmt, nk):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = _decode_tile(w_ref[...], sfmt, scale_ref[0])
    x = x_ref[...].astype(jnp.float32)
    o_ref[...] += jax.lax.dot(x, w,
                              preferred_element_type=jnp.float32)


def dequant_matmul_pallas(x, planes, scale, sfmt: StorageFormat,
                          *, N: int, bm: int = 128, bn: int = 256,
                          bk: int = 512, interpret: bool = False):
    """x [M, K] f32/bf16, planes [nbits, K, N//32] int32 -> [M, N] f32."""
    M, K = x.shape
    nbits, K2, Nw = planes.shape
    assert K2 == K and Nw * LANE == N, (planes.shape, (K, N))
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0
    grid = (M // bm, N // bn, K // bk)
    scale_arr = jnp.asarray(scale, jnp.float32).reshape(1)
    kernel = functools.partial(_dq_matmul_kernel, sfmt=sfmt,
                               nk=K // bk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda mi, ni, ki: (mi, ki)),
            pl.BlockSpec((nbits, bk, bn // LANE),
                         lambda mi, ni, ki: (0, ki, ni)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda mi, ni, ki: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=interpret,
    )(x, planes, scale_arr)
