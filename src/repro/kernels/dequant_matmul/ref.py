"""Pure-jnp oracle for the fused dequant matmul."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import softfloat as sf
from repro.core.bitslice import unpack_planes
from repro.core.fpformat import StorageFormat


def dequant_matmul_ref(x, planes, scale, sfmt: StorageFormat, N: int):
    """x [M,K], planes [nbits,K,N//32] int32 -> [M,N] f32 (unfused)."""
    nbits, K, Nw = planes.shape
    codes = unpack_planes(planes.reshape(nbits, K * Nw))  # [K*Nw*32]
    codes = codes.reshape(K, Nw * 32)[:, :N]
    w = sf.decode_storage(codes, sfmt) * scale
    return x.astype(jnp.float32) @ w
