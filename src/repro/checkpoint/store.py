"""Sharded, elastic, async checkpointing.

Every array is saved as one .npy chunk per *unique* shard (replica 0
only), keyed by the global index bounds of the shard, plus a manifest
with shapes, dtypes, chunk tables and crc32 integrity hashes.  Restore
is layout-free: ``jax.make_array_from_callback`` asks for whatever
slices the *current* mesh needs and the reader assembles them from any
overlapping chunks — so a checkpoint written on (16,16) restores onto
(2,16,16), (4,8), or one CPU device (elastic re-mesh / shrink restart).

Commit protocol: chunks are written into ``step_<n>.tmp/`` and the
directory is atomically renamed to ``step_<n>/`` after the manifest
lands — a crashed writer can never produce a half-valid checkpoint.
``CheckpointManager`` runs saves on a background thread (device->host
transfer is synchronous, file IO is async) and ``wait()`` barriers at
the next save/restore.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import re
import shutil
import threading
import zlib

import jax
import numpy as np


def _leaf_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(_key_str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return names, leaves, treedef


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)


def _chunk_name(name: str, start: tuple, stop: tuple) -> str:
    safe = re.sub(r"[^A-Za-z0-9_.-]", "_", name)
    idx = "_".join(f"{a}-{b}" for a, b in zip(start, stop))
    return f"{safe}__{idx or 'scalar'}.npy"


def save_checkpoint(directory, step: int, state, *, keep: int = 3):
    """Synchronous sharded save.  Returns the checkpoint path."""
    directory = pathlib.Path(directory)
    tmp = directory / f"step_{step}.tmp"
    final = directory / f"step_{step}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    names, leaves, _ = _leaf_paths(state)
    manifest = {"step": step, "leaves": {}}
    for name, leaf in zip(names, leaves):
        arr = leaf if isinstance(leaf, jax.Array) else jax.numpy.asarray(leaf)
        chunks = []
        for shard in arr.addressable_shards:
            if shard.replica_id != 0:
                continue
            idx = shard.index
            start = tuple(s.start or 0 for s in idx)
            stop = tuple(s.stop if s.stop is not None else dim
                         for s, dim in zip(idx, arr.shape))
            data = np.ascontiguousarray(np.asarray(shard.data))
            fname = _chunk_name(name, start, stop)
            # Store raw little-endian bytes: numpy can't round-trip
            # ml_dtypes (bfloat16) through np.save/np.load natively.
            np.save(tmp / fname, data.reshape(-1).view(np.uint8))
            chunks.append({"file": fname, "start": list(start),
                           "stop": list(stop),
                           "shape": [b - a for a, b in zip(start, stop)],
                           "dtype": str(data.dtype),
                           "crc32": zlib.crc32(data.tobytes()) & 0xFFFFFFFF})
        manifest["leaves"][name] = {
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "chunks": chunks,
        }
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    _gc(directory, keep)
    return final


def _gc(directory: pathlib.Path, keep: int):
    steps = sorted(
        (int(p.name.split("_")[1]), p)
        for p in directory.glob("step_*") if p.name.split("_")[1].isdigit())
    for _, p in steps[:-keep] if keep else []:
        shutil.rmtree(p, ignore_errors=True)


def latest_step(directory) -> int | None:
    directory = pathlib.Path(directory)
    if not directory.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in directory.glob("step_*")
             if p.name.split("_")[1].isdigit()
             and (p / "manifest.json").exists()]
    return max(steps) if steps else None


def restore_checkpoint(directory, step: int, abstract_state,
                       shardings=None, *, verify: bool = False):
    """Restore onto the current mesh.  ``abstract_state`` is a pytree of
    ShapeDtypeStructs (or arrays — shapes/dtypes are taken from it);
    ``shardings`` is a matching tree of Shardings (None -> host+commit
    to default device placement)."""
    ckpt = pathlib.Path(directory) / f"step_{step}"
    manifest = json.loads((ckpt / "manifest.json").read_text())

    names, leaves, treedef = _leaf_paths(abstract_state)
    if shardings is not None:
        _, sh_leaves, _ = _leaf_paths(shardings)
    else:
        sh_leaves = [None] * len(leaves)

    out = []
    for name, leaf, sh in zip(names, leaves, sh_leaves):
        meta = manifest["leaves"][name]
        shape = tuple(meta["shape"])
        dtype = np.dtype(meta["dtype"])
        want_shape = tuple(getattr(leaf, "shape", shape))
        assert want_shape == shape, (name, want_shape, shape)
        chunks = meta["chunks"]

        def read_slice(index, _chunks=chunks, _shape=shape, _dtype=dtype,
                       _dir=ckpt, _verify=verify):
            starts = tuple(s.start or 0 for s in index)
            stops = tuple(s.stop if s.stop is not None else dim
                          for s, dim in zip(index, _shape))
            out_arr = np.empty([b - a for a, b in zip(starts, stops)],
                               _dtype)
            for ch in _chunks:
                c0, c1 = ch["start"], ch["stop"]
                inter0 = [max(a, c) for a, c in zip(starts, c0)]
                inter1 = [min(b, c) for b, c in zip(stops, c1)]
                if any(a >= b for a, b in zip(inter0, inter1)) and out_arr.ndim:
                    continue
                raw = np.load(_dir / ch["file"])
                if _verify:
                    crc = zlib.crc32(raw.tobytes()) & 0xFFFFFFFF
                    if crc != ch["crc32"]:
                        raise IOError(f"checksum mismatch in {ch['file']}")
                import jax.numpy as _jnp
                ch_dtype = _jnp.dtype(ch.get("dtype", str(_dtype)))
                data = raw.view(ch_dtype).reshape(ch["shape"])
                if not out_arr.ndim:
                    out_arr[()] = data[()]
                    continue
                src = tuple(slice(a - c, b - c)
                            for a, b, c in zip(inter0, inter1, c0))
                dst = tuple(slice(a - s, b - s)
                            for a, b, s in zip(inter0, inter1, starts))
                out_arr[dst] = data[src]
            return out_arr

        target_dtype = getattr(leaf, "dtype", dtype)
        if sh is None:
            full = read_slice(tuple(slice(0, d) for d in shape))
            out.append(jax.numpy.asarray(full.astype(target_dtype)))
        else:
            arr = jax.make_array_from_callback(
                shape, sh,
                lambda idx, rs=read_slice, td=target_dtype:
                    rs(idx).astype(td))
            out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)


@dataclasses.dataclass
class CheckpointManager:
    """Async checkpointing with a single background writer thread."""
    directory: str
    keep: int = 3
    _thread: threading.Thread | None = None
    _error: list = dataclasses.field(default_factory=list)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error:
            raise self._error.pop()

    def save(self, step: int, state, *, block: bool = False):
        self.wait()
        # Materialize on host synchronously (cheap, local) so the step
        # can mutate `state` immediately; file IO happens off-thread.
        host_state = jax.tree.map(
            lambda x: np.asarray(jax.device_get(x)), state)

        def work():
            try:
                save_checkpoint(self.directory, step, host_state,
                                keep=self.keep)
            except Exception as e:  # surfaced at next wait()
                self._error.append(e)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
        if block:
            self.wait()

    def restore_latest(self, abstract_state, shardings=None):
        self.wait()
        step = latest_step(self.directory)
        if step is None:
            return None, None
        state = restore_checkpoint(self.directory, step, abstract_state,
                                   shardings)
        return step, state
